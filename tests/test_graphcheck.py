"""graphcheck — compiled-graph contract analyzer (docs/design.md #10).

Four layers under test:

* Per-rule positive/negative fixtures: synthetic in-memory GraphSpecs
  that violate exactly one contract (a materialised [n, n] block, a
  smuggled collective, a callback, a dropped donation, an unaudited
  narrowing cast, an over-budget temp) and their clean twins.
* The shipped-tree self-check: the full registry traces with ZERO
  findings and matches the committed golden fingerprints (trace-level
  rules; the big-shape GRC001 compiles run in the dedicated CI job and
  are spot-checked here through one cheap synthetic budget).
* Seeded regression: reverting ``engine.total_loss`` to the
  materialised [n, k] graph trips the analyzer.
* The CLI surface: flags, exit codes, golden drift diff.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.graph import budgets, fingerprint as fp, rules
from repro.analysis.graph.entrypoints import GraphSpec, N, by_name

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "fixtures", "graphs.json")


def _spec(fn, args, *, name="test.synthetic", tags=("hot",), **over):
    kw = over.pop("kwargs", {})
    return GraphSpec(name=name, build=lambda: (fn, args, kw),
                     tags=frozenset(tags), **over)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _analyze_one(spec, **kw):
    report, prints = rules.analyze([spec], with_budgets=kw.pop(
        "with_budgets", False), **kw)
    return report, prints


# ---------------------------------------------------------------------------
# Per-rule positive/negative fixtures
# ---------------------------------------------------------------------------

def test_grc002_flags_materialised_nn_block():
    @jax.jit
    def materialised(x):
        dmat = jnp.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
        return jnp.sum(jnp.min(dmat, axis=1))

    report, _ = _analyze_one(
        _spec(materialised, (_f32(N, 4),), tags=("hot", "streaming")))
    # one finding per distinct materialised intermediate (the broadcast
    # difference, its square, and the reduced [n, n] block)
    assert report.findings and \
        {f.rule for f in report.findings} == {"GRC002"}
    assert f"n={N}" in report.findings[0].message


def test_grc002_clean_on_streamed_form_and_untagged():
    @jax.jit
    def streamed(x):
        def body(acc, row):
            return acc + jnp.min(jnp.sum((x - row) ** 2, axis=1)), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), x)
        return out

    report, _ = _analyze_one(
        _spec(streamed, (_f32(N, 4),), tags=("hot", "streaming")))
    assert report.findings == []

    @jax.jit
    def materialised(x):
        return jnp.sum(x[:, None, :] - x[None, :, :])

    # the same block is legal without the streaming tag (e.g. predict,
    # where [rows, k] IS the product)
    report, _ = _analyze_one(_spec(materialised, (_f32(N, 4),)))
    assert report.findings == []


def _psum_fn():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(jax.devices()[:1], ("i",))

    @jax.jit
    def f(x):
        return shard_map(lambda a: jax.lax.psum(a, "i"), mesh=mesh,
                         in_specs=P("i"), out_specs=P())(x)
    return f


def test_grc003_flags_undeclared_collective():
    report, _ = _analyze_one(_spec(_psum_fn(), (_f32(8),)))
    got = sorted(f.rule for f in report.findings)
    assert got == ["GRC003", "GRC003"]          # psum AND shard_map
    assert any("psum count 1 != declared 0" in f.message
               for f in report.findings)


def test_grc003_clean_when_census_declared():
    report, _ = _analyze_one(
        _spec(_psum_fn(), (_f32(8),),
              collectives={"psum": 1, "shard_map": 1}))
    assert report.findings == []


def test_grc004_flags_callback_and_ignores_const_staging():
    import numpy as np

    @jax.jit
    def with_cb(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct(x.shape, x.dtype),
            x)

    report, _ = _analyze_one(_spec(with_cb, (_f32(8),)))
    assert [f.rule for f in report.findings] == ["GRC004"]
    assert "pure_callback" in report.findings[0].message

    # jnp.asarray on a host table stages a constant via device_put —
    # constant placement, not a runtime round-trip
    table = np.arange(16, dtype=np.float32)

    @jax.jit
    def with_const(x):
        return x + jnp.asarray(table)

    report, _ = _analyze_one(_spec(with_const, (_f32(16),)))
    assert report.findings == []


def test_grc005_flags_dropped_donation():
    def f(x, y):
        return x + y, y

    undonated = jax.jit(f)
    donated = jax.jit(f, donate_argnums=(0,))
    args = (_f32(32), _f32(32))

    report, _ = _analyze_one(_spec(undonated, args, donated_leaves=1))
    assert [f_.rule for f_ in report.findings] == ["GRC005"]
    assert "0 aliased buffer(s)" in report.findings[0].message

    report, _ = _analyze_one(_spec(donated, args, donated_leaves=1))
    assert report.findings == []


def test_grc006_flags_unaudited_narrowing():
    @jax.jit
    def narrowing(x):
        return jnp.sum(x.astype(jnp.bfloat16).astype(jnp.float32))

    spec = _spec(narrowing, (_f32(64),))
    report, _ = _analyze_one(spec)
    assert [f.rule for f in report.findings] == ["GRC006"]
    assert "bfloat16" in report.findings[0].message

    # the widening f32->f64-free cast back up is never flagged, and an
    # audited allowance silences the finding
    report, _ = _analyze_one(
        _spec(narrowing, (_f32(64),), allowed_narrowing=1))
    assert report.findings == []


def test_grc001_budget_positive_negative(monkeypatch):
    n, k = 4096, 64
    monkeypatch.setitem(
        budgets._BUDGETS, "test.synthetic",
        (lambda s: s["n"] * s["k"] * 4 // 10, "n*k*4 // 10 (test)"))
    monkeypatch.setitem(budgets._SHAPES, "test.synthetic",
                        {"n": n, "k": k})

    def materialised(x, med):
        return jnp.sum(jnp.min(
            jnp.sum((x[:, None, :] - med[None, :, :]) ** 2, axis=-1),
            axis=1))

    def streamed(x, med):
        def body(acc, row):
            return acc + jnp.min(jnp.sum((med - row) ** 2, axis=1)), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), x)
        return out

    args = (_f32(n, 4), _f32(k, 4))
    for fn, expect in ((materialised, ["GRC001"]), (streamed, [])):
        spec = _spec(jax.jit(fn), args, budget="test.synthetic")
        spec = dataclasses.replace(spec, build_big=spec.build)
        report, _ = _analyze_one(spec, with_budgets=True)
        assert [f.rule for f in report.findings] == expect, \
            [f.message for f in report.findings]


def test_grc000_drift_positive_negative():
    @jax.jit
    def f(x):
        return jnp.sum(x * x)

    spec = _spec(f, (_f32(16),))
    _, prints = _analyze_one(spec)
    golden = fp.merge_golden(None, prints)

    # clean against its own fingerprint
    report, _ = _analyze_one(spec, golden_doc=golden)
    assert report.findings == []

    # perturb: census drift is reported primitive-by-primitive
    bad = json.loads(json.dumps(golden))
    entry = bad["goldens"][jax.__version__]["test.synthetic"]
    entry["hash"] = "0" * 16
    entry["census"]["dot_general"] = 7
    report, _ = _analyze_one(spec, golden_doc=bad)
    assert [f.rule for f in report.findings] == ["GRC000"]
    assert "dot_general: 7 -> 0 (-7)" in report.findings[0].message

    # a golden for a DIFFERENT jax version is a note, not a finding
    other = {"tool": "graphcheck", "version": 1,
             "goldens": {"0.0.0": {}}}
    report, _ = _analyze_one(spec, golden_doc=other)
    assert report.findings == []
    assert any("no goldens committed" in n for n in report.notes)


# ---------------------------------------------------------------------------
# Shipped-tree self-check + seeded regression
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shipped_report():
    golden = fp.load_golden(GOLDEN) if os.path.isfile(GOLDEN) else None
    return rules.analyze(golden_doc=golden, with_budgets=False)


def test_shipped_tree_is_clean(shipped_report):
    report, _ = shipped_report
    assert report.findings == [], rules.format_human(report)


def test_shipped_tree_matches_committed_golden(shipped_report):
    assert os.path.isfile(GOLDEN), \
        "tests/fixtures/graphs.json missing — REGEN_GOLDEN=1 python -m " \
        "repro.analysis.graph"
    golden = fp.load_golden(GOLDEN)
    vgold = fp.golden_for_version(golden)
    if vgold is None:
        pytest.skip(f"no goldens for jax {jax.__version__}")
    _, prints = shipped_report
    assert sorted(prints) == sorted(vgold)


def test_registry_covers_known_hot_drivers(shipped_report):
    report, _ = shipped_report
    names = set(report.entrypoints)
    for required in ("core._build_fused[pic]", "core._swap_iter[pic]",
                     "core._build_batch[pic]", "core._swap_batch[pic]",
                     "engine.total_loss", "engine.medoid_cache",
                     "kernels.stream_build_g_stats", "kernels.stream_top2",
                     "api.get_predict_fn", "api.get_assign_fn",
                     "dist.build_phase[pic]", "dist.swap_iter[pic]"):
        assert required in names, f"{required} fell out of the registry"


def test_seeded_regression_materialised_total_loss():
    """A revert of engine.total_loss to the pre-streaming materialised
    [n, k] graph must trip the analyzer (GRC002 at trace level)."""
    from repro.core.distances import get_metric

    @jax.jit
    def reverted(data, medoids):
        dmat = get_metric("l2")(data, data[medoids])
        return jnp.sum(jnp.min(dmat, axis=1))

    real = by_name()["engine.total_loss"]
    seeded = GraphSpec(
        name=real.name, build=lambda: (reverted, (_f32(N, 8),
                                                  jax.ShapeDtypeStruct(
                                                      (N,), jnp.int32)), {}),
        tags=real.tags, n=real.n)
    report, _ = _analyze_one(seeded)
    assert "GRC002" in [f.rule for f in report.findings]


def test_budget_formulas_scale_with_shape():
    base = budgets.budget_bytes("engine.total_loss")
    assert budgets.budget_bytes("engine.total_loss",
                                n=2 * budgets.N_BIG) == 2 * base
    assert "n*k*4" in budgets.budget_doc("engine.total_loss")
    for name in budgets.budget_names():
        assert budgets.budget_bytes(name) > 0
        assert budgets.shape_for(name)


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

def _cli(*argv, env_extra=None):
    env = {"PYTHONPATH": os.path.join(REPO, "src"),
           "PATH": "/usr/bin:/bin",
           "HOME": os.environ.get("HOME", "/root")}
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.graph", *argv],
        capture_output=True, text=True, cwd=REPO, env=env)


def test_cli_list_rules_and_entrypoints():
    r = _cli("--list-rules")
    assert r.returncode == 0
    for rid in rules.ALL_RULES:
        assert rid in r.stdout
    r = _cli("--list-entrypoints")
    assert r.returncode == 0
    assert "engine.total_loss" in r.stdout
    assert "core._swap_iter[pic]" in r.stdout


def test_cli_unknown_rule_and_entrypoint_exit_2():
    assert _cli("--rules", "GRC999").returncode == 2
    assert _cli("--entrypoints", "no.such").returncode == 2


def test_cli_single_entrypoint_json_clean():
    r = _cli("--entrypoints", "engine.total_loss", "--skip-budgets",
             "--format", "json")
    assert r.returncode == 0, r.stdout + r.stderr
    doc = json.loads(r.stdout)
    assert doc["tool"] == "graphcheck"
    assert doc["findings"] == []
    assert doc["entrypoints"] == ["engine.total_loss"]
    assert "engine.total_loss" in doc["fingerprints"]


def test_cli_golden_diff_detects_drift(tmp_path):
    golden = fp.load_golden(GOLDEN)
    vgold = fp.golden_for_version(golden)
    if vgold is None:
        pytest.skip(f"no goldens for jax {jax.__version__}")
    bad = json.loads(json.dumps(golden))
    entry = bad["goldens"][jax.__version__]["engine.total_loss"]
    entry["hash"] = "0" * 16
    entry["census"]["dot_general"] = entry["census"].get(
        "dot_general", 0) + 2
    bad_path = tmp_path / "graphs_bad.json"
    bad_path.write_text(json.dumps(bad))
    r = _cli("--entrypoints", "engine.total_loss", "--skip-budgets",
             "--golden", str(bad_path), "--golden-diff")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "dot_general" in r.stdout and "-2" in r.stdout
    # the committed golden itself diffs clean
    r = _cli("--entrypoints", "engine.total_loss", "--skip-budgets",
             "--golden-diff")
    assert r.returncode == 0, r.stdout + r.stderr
