"""Distributed BanditPAM equivalence: 8 simulated devices (subprocess so
the device-count flag doesn't leak into other tests), sharded references,
result must match exact PAM."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    # The mesh/shard_map API used here (and by repro.core.distributed)
    # needs jax >= 0.6; skip cleanly on older installs.
    pytest.skip("needs jax.sharding.AxisType (jax >= 0.6)",
                allow_module_level=True)

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json, numpy as np
    from repro.core import datasets, pam
    from repro.core.distributed import DistributedBanditPAM

    data = datasets.mnist_like(512, seed=3)
    p = pam(data, k=3, metric="l2")

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    d = DistributedBanditPAM(3, mesh, metric="l2", seed=0).fit(data)
    print(json.dumps({
        "pam": sorted(int(m) for m in p.medoids),
        "dist": sorted(int(m) for m in d.medoids),
        "pam_loss": p.loss, "dist_loss": d.loss,
        "evals": d.distance_evals,
    }))
""")


def test_distributed_matches_pam():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # Theorem 2 whp-match; loss equality is the hard invariant
    assert abs(res["pam_loss"] - res["dist_loss"]) / res["pam_loss"] < 1e-4, res
    assert res["pam"] == res["dist"], res
