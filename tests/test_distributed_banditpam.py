"""Distributed BanditPAM equivalence: 8 simulated devices (subprocess so
the device-count flag doesn't leak into other tests), sharded references
over a hierarchical (pod, data) mesh, result must match exact PAM."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json, numpy as np
    from jax.sharding import Mesh
    from repro.core import datasets, pam
    from repro.core.distributed import DistributedBanditPAM

    data = datasets.mnist_like(512, seed=3)
    p = pam(data, k=3, metric="l2")

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    d = DistributedBanditPAM(3, mesh, metric="l2", seed=0).fit(data)
    print(json.dumps({
        "pam": sorted(int(m) for m in p.medoids),
        "dist": sorted(int(m) for m in d.medoids),
        "pam_loss": p.loss, "dist_loss": d.loss,
        "evals": d.distance_evals,
    }))
""")


def test_distributed_matches_pam():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        cwd=str(pathlib.Path(__file__).resolve().parent.parent),
        env=dict(os.environ, PYTHONPATH="src"), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # Theorem 2 whp-match; loss equality is the hard invariant
    assert abs(res["pam_loss"] - res["dist_loss"]) / res["pam_loss"] < 1e-4, res
    assert res["pam"] == res["dist"], res
