"""Pipeline parallelism: GPipe schedule over a 2-stage pod axis must equal
the single-device sequential forward (subprocess: 8 host devices)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    # The mesh axis_types API used by this subprocess needs jax >= 0.6;
    # skip cleanly on older installs.
    pytest.skip("needs jax.sharding.AxisType (jax >= 0.6)",
                allow_module_level=True)

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import PartitionSpec as P
    from repro.distributed.pipeline import pipeline_map

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    D, LAYERS, M, MB = 16, 4, 3, 8
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (LAYERS, D, D)) * 0.3

    def layer(w, x):
        return jnp.tanh(x @ w)

    def stage_fn(wstack, x):            # wstack [LAYERS/2, D, D]
        def body(h, w):
            return layer(w, h), None
        h, _ = jax.lax.scan(body, x, wstack)
        return h

    mbs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

    # reference: all layers sequentially
    ref = mbs
    for i in range(LAYERS):
        ref = jax.vmap(lambda x: layer(ws[i], x))(ref)

    run = pipeline_map(stage_fn, mesh, n_stages=2, axis="pod",
                       params_spec=P("pod"), x_spec=P(None))
    out = run(ws.reshape(2, LAYERS // 2, D, D).reshape(LAYERS, D, D), mbs)
    err = float(jnp.max(jnp.abs(out - ref)))

    # and gradients flow through the schedule
    def loss(w):
        return jnp.sum(run(w, mbs) ** 2)
    g = jax.grad(loss)(ws)
    gfinite = bool(jnp.isfinite(g).all())
    print(json.dumps({"err": err, "gfinite": gfinite}))
""")


def test_gpipe_matches_sequential():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
    assert res["gfinite"], res
