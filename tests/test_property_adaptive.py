"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional [test] extra; skip, don't die
from hypothesis import given, settings, strategies as st

from repro.core.adaptive import adaptive_search
from repro.core.banditpam import _swap_batch_stats, medoid_cache
from repro.core.distances import get_metric


def _mk_stats(values):
    """values: [arms, n_ref] ground-truth g table -> streaming stats_fn."""
    v = jnp.asarray(values)

    def stats_fn(ref_idx, w, lead, rnd):
        g = v[:, ref_idx] * w[None, :]
        return g.sum(1), (g * g).sum(1), g @ g[lead]

    def exact_fn():
        return v.mean(1)

    return stats_fn, exact_fn


@settings(max_examples=15, deadline=None)
@given(
    n_arms=st.integers(3, 40),
    n_ref=st.integers(5, 200),
    seed=st.integers(0, 10_000),
    sampling=st.sampled_from(["permutation", "replacement"]),
    baseline=st.sampled_from(["none", "leader"]),
)
def test_adaptive_search_finds_separated_best(n_arms, n_ref, seed, sampling, baseline):
    """With a clearly separated best arm, Algorithm 1 must return it."""
    rng = np.random.default_rng(seed)
    mu = rng.uniform(1.0, 2.0, size=n_arms)
    best = rng.integers(n_arms)
    mu[best] = 0.0  # separation >> within-arm spread below
    values = mu[:, None] + 0.05 * rng.standard_normal((n_arms, n_ref))
    stats_fn, exact_fn = _mk_stats(values.astype(np.float32))
    res = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_fn,
                          exact_fn=exact_fn, n_arms=n_arms, n_ref=n_ref,
                          batch_size=16, sampling=sampling, baseline=baseline)
    assert int(res.best) == best


@settings(max_examples=15, deadline=None)
@given(n_arms=st.integers(2, 30), n_ref=st.integers(4, 128),
       seed=st.integers(0, 10_000))
def test_permutation_mode_is_exact_at_full_budget(n_arms, n_ref, seed):
    """Sampling without replacement ⇒ winner == exact argmin, always
    (not just w.h.p.), because the final running mean is the exact mean."""
    rng = np.random.default_rng(seed)
    values = rng.standard_normal((n_arms, n_ref)).astype(np.float32)
    stats_fn, exact_fn = _mk_stats(values)
    res = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_fn,
                          exact_fn=exact_fn, n_arms=n_arms, n_ref=n_ref,
                          batch_size=8, sampling="permutation")
    assert int(res.best) == int(np.argmin(values.mean(1)))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 60), k=st.integers(2, 5), b=st.integers(3, 16),
       seed=st.integers(0, 1000))
def test_swap_stats_identity_vs_dense(n, k, b, seed):
    """The FastPAM1 fused sums must equal the dense Eq. 12 evaluation."""
    rng = np.random.default_rng(seed)
    d = rng.uniform(0.1, 3.0, size=(n, 8)).astype(np.float32)
    data = jnp.asarray(d)
    med = jnp.asarray(rng.choice(n, size=k, replace=False).astype(np.int32))
    d1, d2, assign = medoid_cache(data, med, metric="l2")
    ref_idx = jnp.asarray(rng.integers(0, n, size=b).astype(np.int32))
    w = jnp.ones((b,), jnp.float32)
    dxy = get_metric("l2")(data, data[ref_idx])
    sums, sqsums = _swap_batch_stats(dxy, d1[ref_idx], d2[ref_idx],
                                     assign[ref_idx], w, k)
    # dense oracle: g[m, x, y] per Eq. 12
    d1b = np.asarray(d1)[np.asarray(ref_idx)]
    d2b = np.asarray(d2)[np.asarray(ref_idx)]
    ab = np.asarray(assign)[np.asarray(ref_idx)]
    dxy_np = np.asarray(dxy)
    g = np.empty((k, n, b), np.float32)
    for m in range(k):
        in_cm = ab == m
        g[m] = np.where(in_cm[None, :],
                        -d1b[None, :] + np.minimum(d2b[None, :], dxy_np),
                        -d1b[None, :] + np.minimum(d1b[None, :], dxy_np))
    np.testing.assert_allclose(np.asarray(sums).reshape(k, n), g.sum(-1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sqsums).reshape(k, n), (g * g).sum(-1),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n_arms=st.integers(2, 24), n_ref=st.integers(4, 96),
       seed=st.integers(0, 10_000),
       baseline=st.sampled_from(["none", "leader"]))
def test_full_budget_round_gives_exact_mean(n_arms, n_ref, seed, baseline):
    """With batch_size >= n_ref, the single permutation round consumes the
    whole reference set: the final running sums are EXACTLY the population
    sums (integer-valued g keeps f32 addition exact regardless of the
    permutation's summation order), mu_best is the exact mean, and the
    winner is the exact argmin — no 'w.h.p.' hedge at full budget."""
    rng = np.random.default_rng(seed)
    values = rng.integers(0, 8, size=(n_arms, n_ref)).astype(np.float32)
    stats_fn, exact_fn = _mk_stats(values)
    res = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_fn,
                          exact_fn=exact_fn, n_arms=n_arms, n_ref=n_ref,
                          batch_size=n_ref + 8, sampling="permutation",
                          baseline=baseline)
    best = int(res.best)
    assert best == int(np.argmin(values.mean(1)))
    np.testing.assert_array_equal(np.asarray(res.sums), values.sum(1))
    assert float(res.mu_best) == float(
        np.float32(values.sum(1)[best]) / np.float32(n_ref))


@settings(max_examples=15, deadline=None)
@given(n_arms=st.integers(3, 30), n_ref=st.integers(16, 200),
       seed=st.integers(0, 10_000),
       baseline=st.sampled_from(["none", "leader"]))
def test_eliminated_arms_never_reenter(n_arms, n_ref, seed, baseline):
    """Elimination is one-way: the survivor masks observed across rounds
    (via count_fn, which adaptive_search calls on every round's mask) form
    a nested chain — an arm that leaves the active set never comes back."""
    rng = np.random.default_rng(seed)
    values = (rng.uniform(0.0, 2.0, size=(n_arms, 1))
              + 0.3 * rng.standard_normal((n_arms, n_ref))
              ).astype(np.float32)
    stats_fn, exact_fn = _mk_stats(values)
    seen = []

    def record(mask):
        seen.append(np.asarray(mask).copy())

    def counting(active):
        jax.debug.callback(record, active)
        return jnp.sum(active.astype(jnp.uint32))

    res = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_fn,
                          exact_fn=exact_fn, n_arms=n_arms, n_ref=n_ref,
                          batch_size=8, sampling="permutation",
                          baseline=baseline, count_fn=counting)
    jax.effects_barrier()
    assert seen, "count_fn never observed a round"
    # pairwise comparability under ⊆ == the masks form a monotone chain
    # (order-free, so debug-callback delivery order cannot matter)
    for a in seen:
        for b in seen:
            assert (a & ~b).sum() == 0 or (b & ~a).sum() == 0, \
                "an eliminated arm re-entered the active set"
    # the winner survived every round
    best = int(res.best)
    assert all(m[best] for m in seen)


@settings(max_examples=15, deadline=None)
@given(n_arms=st.integers(4, 24), n_ref=st.integers(8, 96),
       seed=st.integers(0, 10_000), dup_gap=st.integers(1, 6))
def test_leader_tie_break_deterministic_under_arm_permutation(
        n_arms, n_ref, seed, dup_gap):
    """Exact fp ties resolve by LOWEST ARM INDEX, deterministically: plant
    the best arm's row at two positions (bit-identical duplicates), and the
    winner must be the earlier copy — under any relabelling of the arms,
    including the leader-baseline path where the pilot leader is itself one
    of the tied arms."""
    rng = np.random.default_rng(seed)
    mu = rng.uniform(1.0, 2.0, size=n_arms)
    i0 = int(rng.integers(0, n_arms - 1))
    i1 = min(n_arms - 1, i0 + dup_gap)
    if i0 == i1:
        i0 = 0
        i1 = n_arms - 1
    mu[i0] = 0.0
    values = (mu[:, None] + 0.05 * rng.standard_normal((n_arms, n_ref))
              ).astype(np.float32)
    values[i1] = values[i0]          # exact duplicate of the best arm
    for baseline in ("none", "leader"):
        stats_fn, exact_fn = _mk_stats(values)
        res = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_fn,
                              exact_fn=exact_fn, n_arms=n_arms, n_ref=n_ref,
                              batch_size=8, sampling="permutation",
                              baseline=baseline)
        assert int(res.best) == min(i0, i1), baseline
        # relabel the arms so the duplicates land at new positions: the
        # winner must follow the relabelling and again be the FIRST copy
        perm = np.asarray(jax.random.permutation(
            jax.random.PRNGKey(seed + 1), n_arms))
        stats_p, exact_p = _mk_stats(values[perm])
        res_p = adaptive_search(jax.random.PRNGKey(seed), stats_fn=stats_p,
                                exact_fn=exact_p, n_arms=n_arms,
                                n_ref=n_ref, batch_size=8,
                                sampling="permutation", baseline=baseline)
        tied = sorted(int(np.where(perm == i)[0][0]) for i in (i0, i1))
        assert int(res_p.best) == tied[0], baseline


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 50), d=st.integers(1, 20), seed=st.integers(0, 1000),
       metric=st.sampled_from(["l2", "l2sq", "l1", "cosine"]))
def test_distance_properties(n, d, seed, metric):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    if metric == "cosine":   # cosine is undefined at ~zero vectors
        x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-3)
    x = jnp.asarray(x)
    dm = np.asarray(get_metric(metric)(x, x))
    assert dm.shape == (n, n)
    # l2's matmul form loses ~1e-5 absolute in f32 cancellation; sqrt
    # amplifies that to ~3e-3 near zero.
    atol = 5e-3 if metric == "l2" else 1e-3
    np.testing.assert_allclose(np.diag(dm), 0.0, atol=atol)
    np.testing.assert_allclose(dm, dm.T, atol=atol)   # these metrics are symmetric
    assert (dm > -1e-4).all()
