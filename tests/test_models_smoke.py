"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, SHAPES
from repro.models import model as M
from repro.train import OptConfig, init_opt_state, make_train_step, synthetic_batch

B, L = 2, 32


def _batch(cfg, b=B, l=L):
    return synthetic_batch(cfg, b, l, step=0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    want_v = cfg.vocab
    assert logits.shape[0] == B and logits.shape[1] == L
    assert logits.shape[-1] == want_v
    assert bool(jnp.isfinite(logits).all()), f"{arch} logits not finite"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_updates(arch):
    cfg = get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    ocfg = OptConfig(lr=1e-3, warmup_steps=2)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, microbatches=2))
    new_params, new_opt, metrics = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, x: acc or bool(x),
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params),
        False)
    assert moved, f"{arch}: no parameter changed"
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_finite(arch):
    cfg = get_reduced(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = M.init_decode_state(cfg, B, 64, dtype=jnp.float32)
    if cfg.frontend == "audio_stub":
        tok = {"tokens": jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)}
    else:
        tok = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, state2 = M.decode_step(cfg, params, state, tok, jnp.int32(0))
    assert bool(jnp.isfinite(logits).all())
    # caches updated in place structure
    assert jax.tree.structure(state) == jax.tree.structure(state2)


def test_full_configs_have_exact_assignment_numbers():
    spec = {
        "falcon_mamba_7b": dict(n_layers=64, d_model=4096, vocab=65024, ssm_state=16),
        "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                            d_ff=4864, vocab=32000, n_experts=128, top_k=2),
        "llama4_scout_17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                 n_kv_heads=8, d_ff=8192, vocab=202048,
                                 n_experts=16, top_k=1),
        "gemma3_12b": dict(n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
                           d_ff=15360, vocab=262144),
        "mistral_nemo_12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=131072),
        "granite_8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                           d_ff=14336, vocab=49152),
        "qwen3_1_7b": dict(n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
                           d_ff=6144, vocab=151936, qk_norm=True),
        "phi3_vision_4_2b": dict(n_layers=32, d_model=3072, n_heads=32,
                                 n_kv_heads=32, d_ff=8192, vocab=32064),
        "zamba2_2_7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, vocab=32000, ssm_state=64),
        "musicgen_large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=2048),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_shape_cells_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288


def test_param_counts_sane():
    # total params should be within ~25% of the advertised sizes
    approx = {"falcon_mamba_7b": 7e9, "gemma3_12b": 12e9,
              "mistral_nemo_12b": 12e9, "granite_8b": 8e9,
              "qwen3_1_7b": 1.7e9, "zamba2_2_7b": 2.7e9,
              "arctic_480b": 480e9}
    for arch, want in approx.items():
        got = get_config(arch).param_count()["total"]
        assert 0.6 * want < got < 1.6 * want, f"{arch}: {got/1e9:.1f}B vs {want/1e9}B"
