"""Elastic restore: a checkpoint saved under one mesh restores onto a
DIFFERENT mesh (data axis shrunk after a simulated host loss) and training
continues bit-exactly — the checkpoint stores global arrays, restore
re-shards via device_put (subprocess: 8 host devices)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

if not hasattr(jax.sharding, "AxisType"):
    # The mesh axis_types API used by this subprocess needs jax >= 0.6;
    # skip cleanly on older installs.
    pytest.skip("needs jax.sharding.AxisType (jax >= 0.6)",
                allow_module_level=True)

_SUBPROC = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.runtime import checkpoint as ckpt
    from repro.runtime.elastic import build_mesh, plan_remesh

    # "before failure": 8 chips, mesh (4 data, 2 model)
    mesh8 = jax.make_mesh((4, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh8 = NamedSharding(mesh8, P("data", "model"))
    w = jax.device_put(jnp.arange(32.0).reshape(8, 4), sh8)
    state = {"w": w, "step": jnp.int32(7)}
    d = tempfile.mkdtemp()
    ckpt.save(d, 7, state, extra={"note": "pre-failure"})

    # "after failure": 2 hosts lost -> plan a 4-chip mesh, same model extent
    plan = plan_remesh(4, model_parallel=2)
    mesh4 = build_mesh(plan)
    sh4 = NamedSharding(mesh4, P("data", "model"))
    restored, meta = ckpt.restore(d, state, shardings={"w": sh4, "step": None})
    ok_val = bool((np.asarray(restored["w"]) == np.asarray(w)).all())
    ok_shard = restored["w"].sharding.mesh.shape == dict(data=2, model=2)
    print(json.dumps({"plan": list(plan.shape), "ok_val": ok_val,
                      "ok_shard": ok_shard, "step": int(meta["step"])}))
""")


def test_restore_onto_smaller_mesh():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["plan"] == [2, 2]
    assert res["ok_val"] and res["ok_shard"] and res["step"] == 7, res
