"""The repro.api facade: solver-parity vs every legacy entrypoint,
precomputed/callable metrics, out-of-sample predict (Pallas vs jnp),
and the FitReport/fit_predict conventions."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (KMedoids, available_metrics, available_solvers,
                       register_solver)
from repro.api import registry as api_registry
from repro.core import (BanditPAM, FitReport, clara, clarans, datasets,
                        fasterpam, onebatchpam, pairwise, pam,
                        resolve_metric, total_loss, voronoi_iteration)
from repro.core.distributed import DistributedBanditPAM, default_mesh

N, K = 300, 3

# solver name -> (facade solver_params, equivalent legacy call)
LEGACY = {
    "banditpam": ({}, lambda d: BanditPAM(K, metric="l2", seed=0).fit(d)),
    "banditpam_pp": ({}, lambda d: BanditPAM(K, metric="l2", seed=0,
                                             reuse="pic").fit(d)),
    # On a single-device host default_mesh() is a 1-device mesh — the
    # sharded machinery (shard_map + psum + stratified draws) still runs.
    "banditpam_dist": ({}, lambda d: DistributedBanditPAM(
        K, default_mesh(), metric="l2", seed=0).fit(d)),
    "pam": ({}, lambda d: pam(d, K, metric="l2", fastpam1=False)),
    "fastpam1": ({}, lambda d: pam(d, K, metric="l2", fastpam1=True)),
    "fasterpam": ({}, lambda d: fasterpam(d, K, metric="l2", seed=0)),
    "clara": ({}, lambda d: clara(d, K, metric="l2", seed=0)),
    "clarans": (dict(max_neighbors=60),
                lambda d: clarans(d, K, metric="l2", seed=0,
                                  max_neighbors=60)),
    "voronoi": ({}, lambda d: voronoi_iteration(d, K, metric="l2", seed=0)),
    # One fixed reference batch, no bandit loop (the serving fast path).
    "onebatchpam": ({}, lambda d: onebatchpam(d, K, metric="l2", seed=0)),
}


@pytest.fixture(scope="module")
def data():
    return datasets.mnist_like(N, seed=11)


def test_every_registered_solver_is_covered():
    assert set(LEGACY) == set(available_solvers())


@pytest.mark.parametrize("solver", sorted(LEGACY))
def test_solver_parity_with_legacy_entrypoint(solver, data):
    """KMedoids(solver=s).fit must be evaluation-for-evaluation identical
    to the legacy entrypoint: same medoids, loss, and ledger."""
    params, legacy_fn = LEGACY[solver]
    est = KMedoids(K, solver=solver, metric="l2", seed=0, **params).fit(data)
    legacy = legacy_fn(data)
    assert isinstance(est.report_, FitReport)
    assert np.array_equal(np.sort(est.medoids_),
                          np.sort(np.asarray(legacy.medoids)))
    assert est.loss_ == pytest.approx(legacy.loss, rel=1e-6)
    assert est.report_.distance_evals == legacy.distance_evals
    assert est.report_.cached_evals == legacy.cached_evals
    assert est.report_.solver == solver
    # every solver's itemised ledger must account for its fresh evals
    fresh = sum(v for ph, v in est.report_.evals_by_phase.items()
                if not ph.endswith("_cached"))
    assert fresh == est.report_.distance_evals
    # in-sample labels: right shape, medoids label themselves
    assert est.labels_.shape == (N,)
    med_order = np.asarray(est.medoids_)
    assert np.array_equal(est.labels_[med_order], np.arange(K))


def test_fit_report_ledger_consistency(data):
    est = KMedoids(K, solver="banditpam_pp", metric="l2", seed=0).fit(data)
    r = est.report_
    ledger = r.ledger()
    assert ledger["fresh"] == r.distance_evals
    assert ledger["cached"] == r.cached_evals > 0
    fresh = sum(v for ph, v in ledger["by_phase"].items()
                if not ph.endswith("_cached"))
    cached = sum(v for ph, v in ledger["by_phase"].items()
                 if ph.endswith("_cached"))
    assert (fresh, cached) == (ledger["fresh"], ledger["cached"])


# ---------------------------------------------------------------------------
# Metrics: precomputed + callable
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dissim(data):
    return np.asarray(pairwise(data, data, metric="l2"))


def test_precomputed_matches_feature_metric(data, dissim):
    a = KMedoids(K, solver="pam", metric="l2").fit(data)
    b = KMedoids(K, solver="pam", metric="precomputed").fit(dissim)
    assert np.array_equal(a.medoids_, b.medoids_)
    assert b.loss_ == pytest.approx(a.loss_, rel=1e-6)
    assert np.array_equal(a.labels_, b.labels_)


def test_precomputed_banditpam_tracks_pam(data, dissim):
    b = KMedoids(K, solver="banditpam", metric="precomputed", seed=0
                 ).fit(dissim)
    p = KMedoids(K, solver="pam", metric="precomputed").fit(dissim)
    assert np.array_equal(np.sort(b.medoids_), np.sort(p.medoids_))
    # the bandit never recomputed a distance: the ledger still counts its
    # algorithmic evaluations, but they were all matrix lookups
    assert b.report_.distance_evals > 0


def test_precomputed_out_of_sample(data, dissim):
    est = KMedoids(K, solver="pam", metric="precomputed").fit(dissim)
    ref = KMedoids(K, solver="pam", metric="l2").fit(data)
    q = datasets.mnist_like(40, seed=5)
    dq = np.asarray(pairwise(jnp.asarray(q), data, metric="l2"))
    np.testing.assert_allclose(est.transform(dq),
                               ref.transform(q, backend="jnp"), rtol=1e-6)
    assert np.array_equal(est.predict(dq), ref.predict(q, backend="jnp"))


def test_precomputed_legacy_misuse_fails_loudly(dissim):
    """A raw (un-augmented) matrix through a legacy entrypoint must raise
    at the first eager distance call, not silently gather garbage."""
    with pytest.raises(ValueError, match="attach_index"):
        pam(jnp.asarray(dissim), K, metric="precomputed")


def test_converged_reporting_semantics(data):
    # solvers with a real stopping criterion report it ...
    assert KMedoids(K, solver="pam").fit(data).report_.converged
    assert KMedoids(K, solver="voronoi", seed=0).fit(data).report_.converged
    # ... budget-exhausting solvers honestly report False
    r = KMedoids(K, solver="clarans", seed=0, max_neighbors=30).fit(data)
    assert not r.report_.converged


def test_precomputed_rejects_bad_shapes(data):
    with pytest.raises(ValueError):
        KMedoids(K, metric="precomputed").fit(data[:10, :20])
    est = KMedoids(K, solver="pam", metric="precomputed").fit(
        np.asarray(pairwise(data[:50], data[:50], metric="l2")))
    with pytest.raises(ValueError):
        est.transform(np.zeros((4, 7), np.float32))  # wrong n_fit


def test_callable_metric_autoregisters():
    def manhattan(x, y):
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)

    small = datasets.hoc4_like(150, seed=0)
    a = KMedoids(2, solver="pam", metric=manhattan).fit(small)
    b = KMedoids(2, solver="pam", metric="l1").fit(small)
    assert np.array_equal(a.medoids_, b.medoids_)
    assert a.loss_ == pytest.approx(b.loss_, rel=1e-5)
    # idempotent resolution under a stable registered name
    name = resolve_metric(manhattan)
    assert resolve_metric(manhattan) == name
    assert name in available_metrics()
    assert a.report_.metric == name


# ---------------------------------------------------------------------------
# Out-of-sample predict/transform: Pallas vs jnp parity, chunking
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fitted(data):
    return KMedoids(K, solver="fastpam1", metric="l2").fit(data)


def test_predict_pallas_jnp_parity(fitted):
    q = datasets.mnist_like(64, seed=3)
    tp = fitted.transform(q, backend="pallas")
    tj = fitted.transform(q, backend="jnp")
    assert tp.shape == tj.shape == (64, K)
    np.testing.assert_allclose(tp, tj, rtol=2e-4, atol=2e-3)
    assert np.array_equal(fitted.predict(q, backend="pallas"),
                          fitted.predict(q, backend="jnp"))


def test_predict_chunking_is_invisible(data, fitted):
    q = datasets.mnist_like(45, seed=4)
    chunked = KMedoids(K, solver="fastpam1", metric="l2",
                       predict_chunk=7).fit(data)
    # chunk boundaries change XLA's matmul tiling, so equality is to ulps
    np.testing.assert_allclose(chunked.transform(q, backend="jnp"),
                               fitted.transform(q, backend="jnp"),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(chunked.predict(q, backend="jnp"),
                          fitted.predict(q, backend="jnp"))


def test_fit_transform_and_train_labels_agree(data, fitted):
    t = fitted.transform(data, backend="jnp")
    assert np.array_equal(np.argmin(t, axis=1), fitted.labels_)
    ft = KMedoids(K, solver="fastpam1", metric="l2").fit_transform(data)
    np.testing.assert_allclose(ft, t, rtol=1e-6)


def test_predict_input_validation(fitted):
    with pytest.raises(ValueError):
        fitted.transform(np.zeros((4, 9), np.float32))  # wrong feature dim
    with pytest.raises(ValueError):
        fitted.transform(np.zeros((4,), np.float32))    # not 2-D
    with pytest.raises(ValueError):
        fitted.transform(np.zeros((4, 784), np.float32), backend="bogus")
    with pytest.raises(ValueError):
        KMedoids(K).predict(np.zeros((4, 784), np.float32))  # not fitted


# ---------------------------------------------------------------------------
# Conventions: fit_predict shapes, registry surface, constructor errors
# ---------------------------------------------------------------------------

def test_facade_fit_predict_returns_labels_only(data):
    est = KMedoids(K, solver="voronoi", metric="l2", seed=0)
    labels = est.fit_predict(data)
    assert isinstance(labels, np.ndarray) and labels.shape == (N,)
    assert np.array_equal(labels, est.labels_)


def test_fit_predict_deprecation_completed(data):
    """The FutureWarned (FitReport, labels) tuple is gone: BanditPAM's
    fit_predict now returns labels only (sklearn convention), silently,
    and agrees with the facade's in-sample assignment."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")     # any warning -> test failure
        labels = BanditPAM(2, metric="l2", seed=0).fit_predict(data[:80])
    assert isinstance(labels, np.ndarray) and labels.shape == (80,)
    assert labels.dtype.kind == "i" and set(np.unique(labels)) <= {0, 1}
    facade = KMedoids(2, solver="banditpam", metric="l2", seed=0)
    assert np.array_equal(labels, facade.fit_predict(data[:80]))


def test_unknown_solver_and_metric_fail_fast(data):
    with pytest.raises(KeyError, match="unknown solver"):
        KMedoids(K, solver="nope").fit(data)
    with pytest.raises(KeyError, match="unknown metric"):
        KMedoids(K, metric="nope").fit(data)
    with pytest.raises(ValueError):
        KMedoids(0)
    with pytest.raises(ValueError):
        KMedoids(K).fit(data[:K])  # need n > k


def test_register_custom_solver(data):
    def firstk(d, k, *, metric, seed, **params):
        med = np.arange(k)
        loss = float(total_loss(jnp.asarray(d), jnp.arange(k), metric=metric))
        return FitReport(medoids=med, loss=loss)

    register_solver("firstk_test", firstk)
    try:
        assert "firstk_test" in available_solvers()
        est = KMedoids(K, solver="firstk_test", metric="l2").fit(data)
        assert np.array_equal(est.medoids_, np.arange(K))
        assert est.labels_.shape == (N,)
        assert est.report_.solver == "firstk_test"
    finally:
        del api_registry._SOLVERS["firstk_test"]
