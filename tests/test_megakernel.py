"""Streaming g-stats megakernel (docs/design.md #8).

Four surfaces under test:

* Pallas streaming kernels (``ops.stream_build_g_stats`` /
  ``stream_swap_g_stats`` / ``stream_top2``) against full-matrix jnp
  oracles — every kernel metric, ragged shapes, reference widths that
  straddle the tile boundary, and argmin tie semantics.
* The jnp streaming forms in ``core.engine`` — BIT-identical to the
  historical materialised graphs wherever the walk guarantees it
  (n <= one tile, the inf-copy-free top-2), and value-equivalent above.
* The serving assignment path (``api.predict.assign_medoids``) through
  the backend top-2 contract.
* The compiled peak-memory regression gate: at large n the streaming
  loss / cache / exact-fallback dispatches must not hold any
  O(n·k) / O(n·chunk) temp — asserted via
  ``jit(...).lower().compile().memory_analysis()``.
"""
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, tuning
from repro.core.distances import get_metric
from repro.kernels import ops

METRICS = list(ops.KERNEL_METRICS)

# (m candidates, r references, d) — r values straddle the 512 reference
# tile (700), sit exactly on it (512), and one step past it (513); m=130
# exercises candidate-tile padding.
SHAPES = [(130, 700, 7), (64, 512, 33), (40, 513, 130)]


def _data(m, r, d, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(r, d)).astype(np.float32))
    return x, y


def _tol(metric):
    # Matmul-lowered metrics accumulate in whatever blocking XLA picks;
    # the kernels' tiling differs from the oracle's one-shot matmul.
    return dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Pallas streaming kernels vs full-matrix oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("m,r,d", SHAPES)
def test_stream_build_matches_oracle(metric, m, r, d):
    x, y = _data(m, r, d)
    rng = np.random.default_rng(1)
    dnear = jnp.asarray(np.abs(rng.normal(size=(r,))).astype(np.float32))
    # a few inf rows exercise the Eq. 4 first-assignment clamp
    dnear = dnear.at[::17].set(jnp.inf)
    w = jnp.asarray((rng.random(r) > 0.1).astype(np.float32))
    lead_g = jnp.asarray(rng.normal(size=(r,)).astype(np.float32)) * w

    dmat = get_metric(metric)(x, y)
    g = jnp.where(jnp.isinf(dnear[None, :]), dmat,
                  jnp.minimum(dmat - dnear[None, :], 0.0)) * w[None, :]
    s, q, c = ops.stream_build_g_stats(x, y, dnear, w, lead_g,
                                       metric=metric, interpret=True)
    np.testing.assert_allclose(s, jnp.sum(g, axis=1), **_tol(metric))
    np.testing.assert_allclose(q, jnp.sum(g * g, axis=1), **_tol(metric))
    np.testing.assert_allclose(c, g @ lead_g, **_tol(metric))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("m,r,d", SHAPES)
def test_stream_swap_matches_oracle(metric, m, r, d):
    k = 4
    x, y = _data(m, r, d, seed=2)
    rng = np.random.default_rng(3)
    dmat_my = get_metric(metric)(y, y[:k])        # refs vs k "medoids"
    assign = jnp.argmin(dmat_my, axis=1).astype(jnp.int32)
    d1 = jnp.min(dmat_my, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, dmat_my.shape, 1)
    d2 = jnp.min(jnp.where(cols == assign[:, None], jnp.inf, dmat_my),
                 axis=1)
    w = jnp.asarray((rng.random(r) > 0.1).astype(np.float32))
    lead_g = jnp.asarray(rng.normal(size=(r,)).astype(np.float32)) * w

    # oracle: Eq. 12 decomposition on the full [m, r] block
    dxy = get_metric(metric)(x, y)
    base = (jnp.minimum(dxy, d1[None, :]) - d1[None, :]) * w[None, :]
    corr = (jnp.minimum(dxy, d2[None, :])
            - jnp.minimum(dxy, d1[None, :]))
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32) * w[:, None]
    sums_o = jnp.sum(base, axis=1)[None, :] + (corr @ onehot).T
    sq_o = (jnp.sum(base * base, axis=1)[None, :]
            + ((2.0 * base * corr + corr * corr) @ onehot).T)
    cross_o = (base @ lead_g)[None, :] + ((corr * lead_g[None, :])
                                          @ onehot).T

    s, q, c = ops.stream_swap_g_stats(x, y, d1, d2, assign, w, k, lead_g,
                                      metric=metric, interpret=True)
    np.testing.assert_allclose(s, sums_o, **_tol(metric))
    np.testing.assert_allclose(q, sq_o, **_tol(metric))
    np.testing.assert_allclose(c, cross_o, **_tol(metric))


@pytest.mark.parametrize("metric", METRICS)
def test_stream_top2_matches_argmin(metric):
    n, d, k = 700, 13, 5
    x, _ = _data(n, 1, d, seed=4)
    med = x[:: n // k][:k]
    dmat = get_metric(metric)(x, med)
    a_ref = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    d1, d2, a = ops.stream_top2(x, med, metric=metric, interpret=True)
    # index choice must match jnp.argmin exactly (first occurrence)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_allclose(d1, jnp.min(dmat, axis=1), **_tol(metric))
    cols = jax.lax.broadcasted_iota(jnp.int32, dmat.shape, 1)
    d2_ref = jnp.min(jnp.where(cols == a_ref[:, None], jnp.inf, dmat),
                     axis=1)
    np.testing.assert_allclose(d2, d2_ref, **_tol(metric))


def test_stream_top2_tie_breaks_to_first_index():
    # duplicated medoid rows: every point ties between columns 1 and 3
    n, d = 260, 9
    x, _ = _data(n, 1, d, seed=5)
    med = jnp.stack([x[7], x[3], x[11], x[3]])    # med[1] == med[3]
    d1, d2, a = ops.stream_top2(x, med, metric="l2sq", interpret=True)
    a_ref = jnp.argmin(get_metric("l2sq")(x, med), axis=1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    # the point sitting ON the duplicated medoid resolves to column 1 and
    # its runner-up is the duplicate at distance 0
    assert int(a[3]) == 1
    assert float(d1[3]) == 0.0 and float(d2[3]) == 0.0


def test_stream_wide_features_rejected():
    x = jnp.zeros((16, ops.DK_MAX + 1), jnp.float32)
    with pytest.raises(ValueError, match="dk budget"):
        ops.stream_build_g_stats(x, x, jnp.zeros((16,)), metric="l2sq",
                                 interpret=True)


# ---------------------------------------------------------------------------
# jnp streaming forms: bit-identity where the walk guarantees it
# ---------------------------------------------------------------------------

def test_medoid_cache_bit_identical_to_inf_copy():
    """The where-masked top-2 must reproduce the historical
    ``.at[arange, assign].set(inf)`` second-minimum bit-for-bit."""
    for metric in ("l2", "l1"):
        x, _ = _data(400, 1, 17, seed=6)
        med_idx = jnp.asarray([3, 99, 250, 7], jnp.int32)

        @jax.jit
        def oracle(data, medoids, metric=metric):
            dmat = get_metric(metric)(data, data[medoids])
            assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
            d1 = jnp.min(dmat, axis=1)
            dmat2 = dmat.at[jnp.arange(dmat.shape[0]), assign].set(jnp.inf)
            return d1, jnp.min(dmat2, axis=1), assign

        got = engine.medoid_cache(x, med_idx, metric=metric)
        want = oracle(x, med_idx)
        for g, o in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(o))


def test_stream_build_sums_bit_identical_small_n():
    """n <= one reference tile: the streaming jnp form must be the
    pre-streaming chunked-scan graph verbatim (golden-ledger contract)."""
    n = 300
    x, _ = _data(n, 1, 21, seed=7)
    dnear = jnp.full((n,), jnp.inf).at[10:].set(1.3)
    be = engine.get_stats_backend("jnp")

    @jax.jit
    def oracle(data, dn):
        idx_np, w_np = engine._ref_chunks(n, engine._EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, w_i = iw
            dxy = be.pairwise(data, data[i], metric="l2")
            s, _, _ = be.build_stats_from_d(dxy, dn[i], w_i, None)
            return acc + s, None

        sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                               (idx, w))
        return sums / n

    # jit on both sides: the drivers only ever run the exact pass inside
    # a traced phase, and bit-parity is a property of the traced graph
    got = jax.jit(lambda data, dn: engine.exact_build_means(
        be, data, dn, metric="l2"))(x, dnear)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle(x, dnear)))


def test_streaming_forms_match_materialised_large_n():
    """Above one tile the walk regroups f32 adds (a documented, narrow
    deviation) — values must still agree to fp tolerance."""
    n, d, k = 1300, 11, 6
    x, _ = _data(n, 1, d, seed=8)
    med_idx = jnp.asarray(np.arange(k) * 200, jnp.int32)
    for metric in ("l2", "l1"):
        dmat = get_metric(metric)(x, x[med_idx])
        d1, d2, a = engine.medoid_cache(x, med_idx, metric=metric)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(jnp.argmin(dmat, axis=1)))
        np.testing.assert_allclose(d1, jnp.min(dmat, axis=1), rtol=1e-5,
                                   atol=1e-5)
        loss = engine.total_loss(x, med_idx, metric=metric)
        np.testing.assert_allclose(
            float(loss), float(jnp.sum(jnp.min(dmat, axis=1))), rtol=1e-5)
    # valid-mask path (batched multi-fit scoring)
    w = jnp.arange(n) < 1000
    lw = engine.total_loss(x, med_idx, metric="l1", w=w)
    dmat = get_metric("l1")(x, x[med_idx])
    np.testing.assert_allclose(
        float(lw), float(jnp.sum(jnp.where(w, jnp.min(dmat, axis=1), 0.0))),
        rtol=1e-5)


def test_stream_columns_matches_pairwise():
    n, c = 1300, 100
    x, _ = _data(n, 1, 19, seed=9)
    be = engine.get_stats_backend("jnp")
    refs = x[:c]
    got = engine.stream_columns(be, x, refs, metric="l1")
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(be.pairwise(x, refs, metric="l1")))
    got2 = engine.stream_columns(be, x, refs, metric="l2")
    np.testing.assert_allclose(got2, be.pairwise(x, refs, metric="l2"),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [300, 700])
def test_exact_means_backend_equivalence(n):
    """jnp and pallas streaming exact passes agree across the tile
    boundary (700 straddles two reference tiles)."""
    x, _ = _data(n, 1, 23, seed=10)
    k = 3
    med_idx = jnp.asarray([1, n // 2, n - 2], jnp.int32)
    d1, d2, a = engine.medoid_cache(x, med_idx, metric="l2sq")
    dnear = d1
    bj = engine.get_stats_backend("jnp")
    bp = engine.get_stats_backend("pallas")
    np.testing.assert_allclose(
        engine.exact_build_means(bj, x, dnear, metric="l2sq"),
        engine.exact_build_means(bp, x, dnear, metric="l2sq"),
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        engine.exact_swap_means(bj, x, d1, d2, a, k, metric="l2sq"),
        engine.exact_swap_means(bp, x, d1, d2, a, k, metric="l2sq"),
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Serving assignment path
# ---------------------------------------------------------------------------

def test_assign_medoids_streaming():
    from repro.api import predict
    n, d, k = 1500, 12, 7
    x, _ = _data(n, 1, d, seed=11)
    med = x[jnp.asarray(np.arange(k) * 200, jnp.int32)]
    labels, dmin = predict.assign_medoids(np.asarray(x), med, "l2",
                                          backend="jnp")
    dmat = get_metric("l2")(x, med)
    np.testing.assert_array_equal(labels,
                                  np.asarray(jnp.argmin(dmat, axis=1)))
    np.testing.assert_allclose(dmin, jnp.min(dmat, axis=1), rtol=1e-5,
                               atol=1e-5)
    # legacy chunk knob: deprecated (warns once per process), still
    # ignored — the answer must not change
    predict._chunk_deprecation_warned = False
    with pytest.warns(DeprecationWarning, match="chunk"):
        l2, m2 = predict.assign_medoids(np.asarray(x), med, "l2",
                                        backend="jnp", chunk=64)
    np.testing.assert_array_equal(labels, l2)
    np.testing.assert_array_equal(dmin, m2)
    # ... and exactly once: the second passing call stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        predict.assign_medoids(np.asarray(x), med, "l2", backend="jnp",
                               chunk=64)
    # closure cache: one compiled variant per (k, d, metric, backend, rows)
    assert predict.get_assign_fn(k, d, "l2", "jnp", 2048) is \
        predict.get_assign_fn(k, d, "l2", "jnp", 2048)
    # empty request
    l0, m0 = predict.assign_medoids(np.zeros((0, d), np.float32), med, "l2",
                                    backend="jnp")
    assert l0.shape == (0,) and m0.shape == (0,)


# ---------------------------------------------------------------------------
# Compiled peak-memory regression gate (satellite: CI memory check)
#
# The byte thresholds are NOT local constants: they are the GRC001
# budget declarations in repro.analysis.graph.budgets, the same bounds
# `python -m repro.analysis.graph` enforces — the gate and the analyzer
# cannot drift apart.
# ---------------------------------------------------------------------------

from repro.analysis.graph import budgets  # noqa: E402

N_BIG, D_BIG, K_BIG = budgets.N_BIG, budgets.D_BIG, budgets.K_BIG


def _temp_bytes(fn, *args):
    ma = jax.jit(fn).lower(*args).compile().memory_analysis()
    if ma is None or not hasattr(ma, "temp_size_in_bytes"):
        pytest.skip("compiled memory_analysis unavailable on this backend")
    return int(ma.temp_size_in_bytes)


def _big_specs():
    return (jax.ShapeDtypeStruct((N_BIG, D_BIG), jnp.float32),
            jax.ShapeDtypeStruct((K_BIG,), jnp.int32))


def test_total_loss_holds_no_nk_block():
    x, med = _big_specs()

    def materialised(data, medoids):
        dmat = get_metric("l2")(data, data[medoids])
        return jnp.sum(jnp.min(dmat, axis=1))

    # the gate must be meaningful: the materialised graph really does
    # hold the O(n·k) block the budget is a tenth of ...
    assert _temp_bytes(materialised, x, med) >= N_BIG * K_BIG * 4
    # ... and the streaming dispatch stays under the declared budget
    streaming = _temp_bytes(
        functools.partial(engine.total_loss, metric="l2"), x, med)
    assert streaming <= budgets.budget_bytes("engine.total_loss"), \
        budgets.budget_doc("engine.total_loss")


def test_medoid_cache_holds_no_nk_block():
    x, med = _big_specs()
    streaming = _temp_bytes(
        functools.partial(engine.medoid_cache, metric="l2"), x, med)
    assert streaming <= budgets.budget_bytes("engine.medoid_cache"), \
        budgets.budget_doc("engine.medoid_cache")


def test_exact_fallback_holds_no_chunk_block():
    x = jax.ShapeDtypeStruct((N_BIG, D_BIG), jnp.float32)
    dn = jax.ShapeDtypeStruct((N_BIG,), jnp.float32)
    be = engine.get_stats_backend("jnp")
    streaming = _temp_bytes(
        lambda data, dnear: engine.exact_build_means(be, data, dnear,
                                                     metric="l2"), x, dn)
    assert streaming <= budgets.budget_bytes("engine.exact_build_means"), \
        budgets.budget_doc("engine.exact_build_means")


# ---------------------------------------------------------------------------
# Tile tuner
# ---------------------------------------------------------------------------

def test_tuner_heuristic_and_ledger():
    tuning.clear_ledger()
    try:
        base = tuning.resolve_tile_config(4096, 128, 8, device_kind="tpu",
                                          backend="pallas")
        assert base.tb == tuning.REF_TILE == engine._EXACT_CHUNK
        cands = list(tuning.candidates(4096, 128, 8, device_kind="tpu",
                                       backend="pallas"))
        assert base in cands and len(cands) > 1
        other = next(c for c in cands if c != base)
        # a faster measurement flips the resolution to the observed config
        tuning.observe(4096, 128, 8, base, {"build": 2.0, "swap": 2.0},
                       device_kind="tpu", backend="pallas")
        tuning.observe(4096, 128, 8, other, {"build": 0.5, "swap": 0.5},
                       device_kind="tpu", backend="pallas")
        got = tuning.resolve_tile_config(4096, 128, 8, device_kind="tpu",
                                         backend="pallas")
        assert got == other
        # shape buckets: a nearby n resolves through the same key
        assert tuning.resolve_tile_config(4097, 128, 8, device_kind="tpu",
                                          backend="pallas") != other
        snap = tuning.ledger_snapshot()
        assert any(other in v for v in snap.values())
    finally:
        tuning.clear_ledger()


def test_tuner_cpu_pallas_floor():
    cfg = tuning.resolve_tile_config(100_000, 784, 10, device_kind="cpu",
                                     backend="pallas")
    assert cfg.tm == 128 and cfg.tb == tuning.REF_TILE
