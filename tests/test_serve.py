"""The streaming serving layer: MedoidService end-to-end (fit -> serve ->
drift -> warm refit), ledger-verified warm-vs-cold refit economics,
bit-identical snapshot/resume, reservoir/drift determinism, the cached
predict closures, and the onebatchpam solver."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (available_solvers, get_predict_fn, medoid_distances,
                       solver_accepts_backend, KMedoids)
from repro.api.predict import assign_medoids, bucket_rows
from repro.core import BanditPAM, datasets, onebatchpam, pairwise, pam
from repro.serve import DriftMonitor, IngestResult, MedoidService, Reservoir

K, D = 5, 20


def _base(n=500, seed=0):
    return datasets.mnist_like(n, seed=seed, d=D)


def _drifted(n, seed, shift=0.5):
    return datasets.mnist_like(n, seed=seed, d=D) + np.float32(shift)


def _service(seed=0, **kw):
    kw.setdefault("reservoir_size", 256)
    kw.setdefault("drift_threshold", 0.2)
    kw.setdefault("drift_window", 100)
    kw.setdefault("request_chunk", 256)
    return MedoidService(K, "l2", seed=seed, **kw)


# ---------------------------------------------------------------------------
# end-to-end acceptance: fit -> serve -> drift -> warm refit beats cold
# ---------------------------------------------------------------------------

def test_service_end_to_end_warm_refit_beats_cold():
    X = _base()
    svc = _service().fit(X)
    base_stats = svc.stats()
    assert base_stats["n_refits"] == 0 and base_stats["seen"] == 500

    # served predictions agree with the offline predict path
    q = _base(64, seed=9)
    ref_lab, _ = assign_medoids(q, svc.medoid_points, "l2", backend="jnp")
    assert np.array_equal(svc.predict(q), ref_lab)

    # ingest a drifted stream until the monitor trips a warm refit
    stream = _drifted(600, seed=3)
    reports = []
    for lo in range(0, 600, 100):
        r = svc.ingest(stream[lo:lo + 100])
        assert isinstance(r, IngestResult)
        if r.refit is not None:
            reports.append(r.refit)
    assert reports, "drifted stream never triggered a refit"
    assert svc.stats()["n_refits"] == len(reports)
    # every auto-refit went through the warm path: BUILD ledger is zero
    for rep in reports:
        assert rep.evals_by_phase["build"] == 0
        assert rep.ledger()["cached"] > 0

    # ledger-verified economics on the SAME refit sample + seed:
    # warm reaches loss <= cold with strictly fewer fresh evals
    warm, cold = svc.refit_report_pair()
    assert warm.loss <= cold.loss + 1e-5 * abs(cold.loss)
    assert warm.ledger()["fresh"] < cold.ledger()["fresh"]
    assert warm.ledger()["cached"] > 0
    assert warm.evals_by_phase["build"] == 0
    assert cold.evals_by_phase["build"] > 0


def test_service_snapshot_resume_bit_identical(tmp_path):
    """Snapshot mid-stream; the resumed service must replay the remaining
    stream to the SAME refits, medoids (bitwise) and ledger."""
    X = _base()
    svc = _service().fit(X)
    pre = _drifted(200, seed=5, shift=0.3)
    for lo in range(0, 200, 100):
        svc.ingest(pre[lo:lo + 100])

    svc.snapshot(str(tmp_path))
    svc2 = MedoidService.restore(str(tmp_path))
    assert np.asarray(svc.medoid_points).tobytes() == \
        np.asarray(svc2.medoid_points).tobytes()
    assert svc.stats() == svc2.stats()

    post = _drifted(400, seed=7, shift=0.8)
    n_refits = 0
    for lo in range(0, 400, 80):
        a = svc.ingest(post[lo:lo + 80])
        b = svc2.ingest(post[lo:lo + 80])
        assert np.array_equal(a.labels, b.labels)
        assert a.dmin.tobytes() == b.dmin.tobytes()
        assert (a.refit is None) == (b.refit is None)
        if a.refit is not None:
            n_refits += 1
            # same refit sample => same medoid indices and ledger
            assert np.array_equal(a.refit.medoids, b.refit.medoids)
            assert a.refit.ledger() == b.refit.ledger()
    assert n_refits >= 1, "resumed segment never refitted"
    assert np.asarray(svc.medoid_points).tobytes() == \
        np.asarray(svc2.medoid_points).tobytes()
    assert svc.stats() == svc2.stats()
    # reservoir state replayed exactly (A-Res keys are f64-exact)
    assert svc.reservoir.keys.tobytes() == svc2.reservoir.keys.tobytes()
    assert np.array_equal(svc.reservoir.sidx, svc2.reservoir.sidx)


def test_drift_trigger_determinism():
    """Two identical services on the same stream refit at the same chunk
    on the same reservoir points and land on identical medoids."""
    X = _base()
    a = _service().fit(X)
    b = _service().fit(X)
    stream = _drifted(600, seed=3)
    trip_a, trip_b = [], []
    for lo in range(0, 600, 100):
        ra = a.ingest(stream[lo:lo + 100])
        rb = b.ingest(stream[lo:lo + 100])
        if ra.refit is not None:
            trip_a.append(lo)
        if rb.refit is not None:
            trip_b.append(lo)
    assert trip_a and trip_a == trip_b
    assert np.array_equal(a.reservoir.sidx, b.reservoir.sidx)
    assert np.asarray(a.medoid_points).tobytes() == \
        np.asarray(b.medoid_points).tobytes()


def test_service_onebatch_refit_path():
    X = _base()
    svc = _service(refit="onebatch",
                   refit_params={"ref_size": 128}).fit(X)
    stream = _drifted(600, seed=3)
    reports = [r.refit for lo in range(0, 600, 100)
               for r in [svc.ingest(stream[lo:lo + 100])]
               if r.refit is not None]
    assert reports
    for rep in reports:
        # the fixed-batch ledger: one [n, b] block + the exact final pass
        assert set(rep.evals_by_phase) == {"ref_batch", "final_loss"}


def test_service_validation():
    with pytest.raises(ValueError):
        MedoidService(0, "l2")
    with pytest.raises(ValueError):
        MedoidService(3, "precomputed")
    with pytest.raises(ValueError):
        MedoidService(3, "l2", refit="nope")
    with pytest.raises(ValueError):
        MedoidService(3, "l2", reservoir_weights="nope")
    svc = MedoidService(3, "l2")
    with pytest.raises(RuntimeError):
        svc.predict(np.zeros((4, D), np.float32))


# ---------------------------------------------------------------------------
# reservoir + drift units
# ---------------------------------------------------------------------------

def test_reservoir_chunking_invariance():
    pts = _base(300, seed=1)
    w = np.abs(pts[:, 0].astype(np.float64)) + 0.1
    r1 = Reservoir(64, D, seed=0)
    r1.offer(pts, w)
    r2 = Reservoir(64, D, seed=0)
    for lo in range(0, 300, 37):                  # ragged chunking
        r2.offer(pts[lo:lo + 37], w[lo:lo + 37])
    assert r1.seen == r2.seen == 300
    assert np.array_equal(r1.sidx, r2.sidx)
    assert r1.keys.tobytes() == r2.keys.tobytes()
    assert np.array_equal(r1.points, r2.points)


def test_reservoir_weighting_biases_survival():
    """Heavily-weighted points must dominate the kept set."""
    pts = np.arange(2000, dtype=np.float32)[:, None] * np.ones((1, D),
                                                               np.float32)
    w = np.where(np.arange(2000) < 1000, 100.0, 0.01)
    r = Reservoir(200, D, seed=0)
    r.offer(pts, w)
    heavy = (r.sidx[:r.filled] < 1000).mean()
    assert heavy > 0.95


def test_reservoir_validation():
    r = Reservoir(8, D, seed=0)
    with pytest.raises(ValueError):
        r.offer(np.zeros((3, D + 1), np.float32))
    with pytest.raises(ValueError):
        r.offer(np.zeros((3, D), np.float32), np.array([1.0, -1.0, 2.0]))
    r.offer(np.zeros((0, D), np.float32))          # empty offer is a no-op
    assert r.seen == 0 and len(r) == 0


def test_drift_monitor_rule():
    m = DriftMonitor(threshold=0.5, window=10)
    m.reset(1.0)
    m.update(np.full(9, 10.0))
    assert not m.drifted                           # below window
    m.update(np.full(1, 10.0))
    assert m.drifted                               # mean 10 > 1.5 * 1.0
    m.reset(10.0)
    m.update(np.full(20, 10.0))
    assert not m.drifted                           # at baseline
    unarmed = DriftMonitor(threshold=0.0, window=1)
    unarmed.update(np.full(5, 1e9))
    assert not unarmed.drifted                     # never reset => inf mu0


# ---------------------------------------------------------------------------
# predict closures (the no-retrace hot path)
# ---------------------------------------------------------------------------

def test_predict_closure_is_cached_and_bucketed():
    assert bucket_rows(1, 8192) == 1
    assert bucket_rows(3, 8192) == 4
    assert bucket_rows(4096, 8192) == 4096
    assert bucket_rows(5000, 8192) == 8192
    assert bucket_rows(10**6, 8192) == 8192
    f1 = get_predict_fn(K, D, "l2", "jnp", 256)
    f2 = get_predict_fn(K, D, "l2", "jnp", 256)
    assert f1 is f2                                # memoised => no retrace
    assert f1 is not get_predict_fn(K, D, "l2", "jnp", 512)


def test_predict_paths_match_reference():
    X = _base(200, seed=2)
    med = jnp.asarray(X[:K])
    ref = np.asarray(pairwise(jnp.asarray(X), med, metric="l2"))
    # ragged sizes exercise the padding path
    for m in (1, 7, 200):
        got = medoid_distances(X[:m], med, "l2", backend="jnp", chunk=64)
        np.testing.assert_allclose(got, ref[:m], rtol=1e-6, atol=1e-6)
    labels, dmin = assign_medoids(X, med, "l2", backend="jnp")
    assert np.array_equal(labels, ref.argmin(axis=1))
    np.testing.assert_allclose(dmin, ref.min(axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# onebatchpam solver
# ---------------------------------------------------------------------------

def test_onebatchpam_tracks_pam_on_full_batch():
    """With ref_size = n the batch objective IS the true objective: the
    solver must match exact PAM's loss closely."""
    X = _base(220, seed=4)
    p = pam(X, K, metric="l2")
    r = onebatchpam(X, K, metric="l2", seed=0, ref_size=220)
    assert r.loss <= p.loss * 1.05
    assert r.converged
    assert r.distance_evals == 220 * 220 + 220 * K
    assert r.ledger()["cached"] == 0


def test_onebatchpam_warm_init_and_validation():
    X = _base(220, seed=4)
    r = onebatchpam(X, K, metric="l2", seed=0)
    rw = onebatchpam(X, K, metric="l2", seed=0, init=r.medoids)
    # warm-starting from the solver's own optimum must keep its loss
    assert rw.loss <= r.loss + 1e-5 * abs(r.loss)
    with pytest.raises(ValueError):
        onebatchpam(X, K, metric="l2", init=[0, 1])           # wrong k
    with pytest.raises(ValueError):
        onebatchpam(X, K, metric="l2", init=[0, 0, 1, 2, 3])  # duplicate
    with pytest.raises(ValueError):
        onebatchpam(X, K, metric="l2", init=[0, 1, 2, 3, 900])
    with pytest.raises(ValueError):
        onebatchpam(X[:K], K, metric="l2")                    # n <= k


def test_onebatchpam_registered_on_facade():
    assert "onebatchpam" in available_solvers()
    assert solver_accepts_backend("onebatchpam")
    X = _base(220, seed=4)
    est = KMedoids(K, solver="onebatchpam", metric="l2", seed=0,
                   ref_size=128).fit(X)
    legacy = onebatchpam(X, K, metric="l2", seed=0, ref_size=128)
    assert np.array_equal(np.sort(est.medoids_),
                          np.sort(np.asarray(legacy.medoids)))
    assert est.report_.distance_evals == legacy.distance_evals


def test_banditpam_warm_start_contract():
    X = _base(300, seed=6)
    cold = BanditPAM(K, reuse="pic", seed=0).fit(X)
    warm = BanditPAM(K, reuse="pic", seed=0).fit(X, warm_start=cold.medoids)
    # warm-starting from the cold optimum: no BUILD evals, loss kept
    assert warm.evals_by_phase["build"] == 0
    assert warm.loss <= cold.loss + 1e-5 * abs(cold.loss)
    assert warm.distance_evals < cold.distance_evals
    with pytest.raises(ValueError):
        BanditPAM(K, seed=0).fit(X, warm_start=[0, 1, 2])
    with pytest.raises(ValueError):
        BanditPAM(K, seed=0).fit(X, warm_start=[0, 0, 1, 2, 3])
    with pytest.raises(ValueError):
        BanditPAM(K, seed=0).fit(X, warm_start=[0, 1, 2, 3, 300])


# ---------------------------------------------------------------------------
# package front
# ---------------------------------------------------------------------------

def test_serve_package_fronts_medoid_service():
    import repro.serve as serve
    assert serve.__all__ == ["DriftMonitor", "IngestResult",
                             "MedoidService", "Reservoir"]
    # the LM scaffolding is quarantined but importable explicitly
    from repro.serve.lm import make_decode_step, make_prefill_step  # noqa
    assert not hasattr(serve, "make_prefill_step")
