"""BanditPAM++ SWAP-phase reuse engine (reuse="pic"): medoid parity with
reuse="none", the fresh/cached distance-evaluation ledger, and the
FasterPAM eager-swap loss-parity reference."""
import pytest

from repro.core import BanditPAM, datasets, fasterpam, pam


# ---------------------------------------------------------------------------
# PIC medoid parity (acceptance: identical medoids on fixed seeds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "cosine"])
@pytest.mark.parametrize("k", [3, 5, 10])
def test_pic_matches_none_medoids(metric, k):
    data = datasets.mnist_like(500, seed=13)
    a = BanditPAM(k, metric=metric, seed=0, reuse="none").fit(data)
    b = BanditPAM(k, metric=metric, seed=0, reuse="pic").fit(data)
    assert sorted(a.medoids.tolist()) == sorted(b.medoids.tolist())
    assert b.loss == pytest.approx(a.loss, rel=1e-5)
    # carried statistics must actually be exercised (cached reads > 0)
    assert b.cached_evals > 0


def test_pic_matches_none_large_n_and_ledger():
    """n=2000 / k=5: same medoids, and the reuse engine pays >= 2x fewer
    fresh SWAP-phase evaluations on a multi-swap run (acceptance bar)."""
    data = datasets.mnist_like(2000, seed=1)
    a = BanditPAM(5, metric="l2", seed=0, reuse="none").fit(data)
    b = BanditPAM(5, metric="l2", seed=0, reuse="pic").fit(data)
    assert sorted(a.medoids.tolist()) == sorted(b.medoids.tolist())
    assert a.n_swaps == b.n_swaps
    assert a.n_swaps >= 2  # multi-swap run, else the ledger claim is vacuous
    assert a.evals_by_phase["swap"] >= 2 * b.evals_by_phase["swap"]
    assert b.evals_by_phase["swap_cached"] > 0
    # total fresh work must go down too, not just be reshuffled across phases
    assert b.distance_evals < a.distance_evals


def test_pic_ledger_split_is_consistent():
    data = datasets.mnist_like(500, seed=13)
    b = BanditPAM(5, metric="l2", seed=0, reuse="pic").fit(data)
    fresh = sum(v for ph, v in b.evals_by_phase.items()
                if not ph.endswith("_cached"))
    cached = sum(v for ph, v in b.evals_by_phase.items()
                 if ph.endswith("_cached"))
    assert b.distance_evals == fresh
    assert b.cached_evals == cached
    assert {"build", "swap", "build_cached", "swap_cached"} <= set(
        b.evals_by_phase)


def test_pic_requires_permutation_sampling():
    with pytest.raises(ValueError):
        BanditPAM(3, sampling="replacement", reuse="pic")
    with pytest.raises(ValueError):
        BanditPAM(3, reuse="bogus")


def test_pic_tracks_pam():
    """Reuse must not change the answer: pic still matches exact PAM."""
    data = datasets.mnist_like(500, seed=7)
    p = pam(data, k=3, metric="l2")
    b = BanditPAM(3, metric="l2", seed=0, reuse="pic").fit(data)
    assert sorted(p.medoids.tolist()) == sorted(b.medoids.tolist())


def test_pic_composes_with_leader_baseline():
    data = datasets.mnist_like(500, seed=13)
    a = BanditPAM(5, metric="l2", seed=0, baseline="leader",
                  reuse="none").fit(data)
    b = BanditPAM(5, metric="l2", seed=0, baseline="leader",
                  reuse="pic").fit(data)
    assert sorted(a.medoids.tolist()) == sorted(b.medoids.tolist())


# ---------------------------------------------------------------------------
# FasterPAM eager-swap reference
# ---------------------------------------------------------------------------

def test_fasterpam_loss_parity_with_pam():
    data = datasets.mnist_like(500, seed=7)
    p = pam(data, 5, metric="l2")
    f = fasterpam(data, 5, metric="l2", seed=0)
    # Both are 1-swap local optima of the same neighbourhood; eager order
    # may land elsewhere, but the loss must be on par.
    assert f.loss <= p.loss * 1.02
    assert len(set(f.medoids.tolist())) == 5
    assert f.distance_evals < p.distance_evals


def test_fasterpam_from_build_init_never_worse():
    data = datasets.scrna_like(400, seed=3)
    p = pam(data, 5, metric="l1")
    f = fasterpam(data, 5, metric="l1", seed=0, init=p.medoids)
    # Seeded at PAM's optimum there is no improving swap: it must stay put.
    assert f.n_swaps == 0
    assert f.loss == pytest.approx(p.loss, rel=1e-5)


def test_fasterpam_parity_bounds_banditpam_pic():
    """The reuse engine's answer is as good as the eager-swap reference."""
    data = datasets.mnist_like(500, seed=13)
    b = BanditPAM(5, metric="l2", seed=0, reuse="pic").fit(data)
    f = fasterpam(data, 5, metric="l2", seed=0)
    assert b.loss <= f.loss * 1.02
