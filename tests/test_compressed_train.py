"""End-to-end compressed-gradient training on a (pod, data) mesh:
loss must track the uncompressed step closely (error feedback), and the
HLO must actually carry int8 on the pod axis (subprocess: 8 devices)."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest

if not hasattr(jax, "shard_map"):
    # repro.train.compressed drives partial-auto shard_map via the jax>=0.6
    # top-level API; on older jax the experimental fallback aborts inside
    # this XLA build's SPMD partitioner (HandleWhile), so skip cleanly.
    pytest.skip("needs jax.shard_map (jax >= 0.6)", allow_module_level=True)

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.train import OptConfig, init_opt_state, make_train_step, synthetic_batch
    from repro.train.compressed import init_pod_residuals, make_compressed_train_step

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = get_reduced("qwen3_1_7b")
    ocfg = OptConfig(lr=5e-3, warmup_steps=2)

    def run(compressed: bool, steps=8):
        params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        opt = init_opt_state(params, ocfg)
        losses = []
        if compressed:
            res = init_pod_residuals(params, 2)
            step = jax.jit(make_compressed_train_step(cfg, ocfg, mesh))
            for i in range(steps):
                b = synthetic_batch(cfg, 8, 32, i)
                params, opt, res, m = step(params, opt, res, b)
                losses.append(float(m["loss"]))
        else:
            step = jax.jit(make_train_step(cfg, ocfg, 1))
            for i in range(steps):
                b = synthetic_batch(cfg, 8, 32, i)
                params, opt, m = step(params, opt, b)
                losses.append(float(m["loss"]))
        return losses

    base = run(False)
    comp = run(True)
    # int8 actually on the wire?
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = init_opt_state(params, ocfg)
    res = init_pod_residuals(params, 2)
    step = make_compressed_train_step(cfg, ocfg, mesh)
    txt = jax.jit(step).lower(params, opt, res,
                              synthetic_batch(cfg, 8, 32, 0)).compile().as_text()
    int8_wire = ("s8[" in txt) and ("all-gather" in txt or "all-reduce" in txt)
    print(json.dumps({"base": base, "comp": comp, "int8_wire": bool(int8_wire)}))
""")


def test_compressed_training_tracks_exact():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["int8_wire"], "no int8 collective found in HLO"
    base, comp = res["base"], res["comp"]
    assert comp[-1] < comp[0], "compressed training must converge"
    # error feedback: final losses within a few percent of exact
    assert abs(comp[-1] - base[-1]) / base[-1] < 0.05, (base, comp)
