"""tracecheck tests: rule corpus, suppressions, reports, CLI, import
graph, and the runtime transfer-guard/dispatch fixtures.

The static half runs on the fixture corpus under
``tests/fixtures/tracecheck`` (``bad/`` known violations, ``clean/``
known-conformant counterparts) plus a self-check over the shipped
``src/repro`` tree; the runtime half drives full ``BanditPAM.fit`` under
``jax.transfer_guard("disallow")`` and asserts the one-dispatch-per-
phase ledger in-test.
"""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import config as cfg_mod
from repro.analysis import engine, imports
from repro.analysis.guard import expected_dispatches

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"
CORPUS = Path(__file__).parent / "fixtures" / "tracecheck"

ALL_RULES = ("TRC000", "TRC001", "TRC002", "TRC003", "TRC004", "TRC005")


def _run(path):
    return engine.run([str(path)], cfg_mod.default_config())


def _rules_hit(report):
    return set(report.counts)


# ---------------------------------------------------------------- static

def test_bad_corpus_fires_every_rule():
    report = _run(CORPUS / "bad")
    assert _rules_hit(report) == set(ALL_RULES)
    assert len(report.findings) >= len(ALL_RULES)


@pytest.mark.parametrize("rule,path_suffix", [
    ("TRC001", "bad/core/hot_loop.py"),
    ("TRC002", "bad/core/hot_loop.py"),
    ("TRC003", "bad/core/rng.py"),
    ("TRC004", "bad/core/stats_backend.py"),
    ("TRC005", "bad/core/banditpam.py"),
    ("TRC005", "bad/kernels/stream.py"),
    ("TRC005", "bad/serve/drift.py"),
    ("TRC005", "bad/runtime/checkpoint.py"),
    ("TRC000", "bad/core/suppressed.py"),
])
def test_rule_positive_location(rule, path_suffix):
    report = _run(CORPUS / "bad")
    hits = [f for f in report.findings
            if f.rule == rule and f.path.endswith(path_suffix)]
    assert hits, f"{rule} did not fire in {path_suffix}"
    assert all(f.line > 0 for f in hits)


def test_clean_corpus_has_no_findings():
    report = _run(CORPUS / "clean")
    assert report.findings == []
    # ...and the justified suppression in clean/core/engine.py counted.
    assert report.suppressed >= 1


def test_host_orchestration_is_not_flagged():
    # hot_loop.host_driver syncs and loops freely — not jit-reachable.
    report = _run(CORPUS / "bad" / "core" / "hot_loop.py")
    assert not any(f.function == "host_driver" for f in report.findings)


def test_bare_suppression_suppresses_but_raises_trc000():
    report = _run(CORPUS / "bad" / "core" / "suppressed.py")
    assert [f.rule for f in report.findings] == ["TRC000"]
    assert report.suppressed == 1


def test_justified_suppression_is_silent():
    report = _run(CORPUS / "clean" / "core" / "engine.py")
    assert report.findings == []
    assert report.suppressed == 1


def test_shipped_tree_is_clean_under_shipped_config():
    report = _run(SRC)
    assert report.findings == [], "\n" + engine.format_human(report)
    # The tree's suppressions all carry justifications (else TRC000
    # findings would have failed the assert above) and are in use.
    assert report.suppressed > 0


def test_json_report_schema():
    report = _run(CORPUS / "bad")
    doc = engine.report_to_json(report)
    assert doc["tool"] == "tracecheck" and doc["version"] == 1
    assert doc["files_scanned"] == report.files_scanned
    assert sum(doc["counts"].values()) == len(doc["findings"])
    for f in doc["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message",
                          "function"}
        assert f["rule"] in ALL_RULES
    json.dumps(doc)  # serializable


# ------------------------------------------------------------------ CLI

def _cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )


def test_cli_nonzero_on_violations_and_json(tmp_path):
    out = tmp_path / "report.json"
    proc = _cli(str(CORPUS / "bad"), "--format", "json",
                "--output", str(out))
    assert proc.returncode == 1, proc.stderr
    doc = json.loads(proc.stdout)
    assert set(doc["counts"]) == set(ALL_RULES)
    assert json.loads(out.read_text()) == doc


def test_cli_zero_on_shipped_tree():
    proc = _cli("src/repro")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_cli_rule_filter_and_list():
    proc = _cli(str(CORPUS / "bad"), "--rules", "TRC004")
    assert proc.returncode == 1
    assert "TRC004" in proc.stdout and "TRC001" not in proc.stdout
    proc = _cli("--list-rules")
    assert proc.returncode == 0
    for rid in ALL_RULES:
        assert rid in proc.stdout


# --------------------------------------------------------- import graph

def test_import_graph_classification():
    cfg = cfg_mod.default_config()
    report = imports.build_report(str(REPO), cfg)
    assert report["repro.api.estimator"]["status"] == "live"
    assert report["repro.core.banditpam"]["status"] == "live"
    assert report["repro.runtime.checkpoint"]["status"] == "live"
    # LM scaffolding is dormant and quarantined.
    for mod in ("repro.models.model", "repro.train.train_step",
                "repro.serve.lm", "repro.runtime.fault"):
        assert report[mod]["status"] != "live", mod
        assert mod in cfg.quarantine


def test_quarantine_contract_holds():
    cfg = cfg_mod.default_config()
    report = imports.build_report(str(REPO), cfg)
    undocumented, stale = imports.check_quarantine(report, cfg)
    assert undocumented == [], f"undocumented dormant modules: {undocumented}"
    assert stale == [], f"stale quarantine entries: {stale}"


# -------------------------------------------------------- runtime guard

@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(3)
    return np.concatenate(
        [rng.normal(loc=c, size=(60, 5)) for c in (0.0, 5.0, 10.0)]
    ).astype(np.float32)


@pytest.mark.parametrize("reuse", ["pic", "none"])
def test_transfer_guard_full_fit(fit_guard, blobs, reuse):
    from repro.core.banditpam import BanditPAM
    report = fit_guard.fit(BanditPAM(3, seed=0, reuse=reuse), blobs)
    # The in-test dispatch contract: one fused BUILD dispatch, one
    # dispatch per SWAP iteration (n_swaps accepts + converging reject).
    iters = report.n_swaps + (1 if report.converged else 0)
    assert report.dispatches_by_phase == {"build": 1, "swap": iters}


def test_transfer_guard_warm_start(fit_guard, blobs):
    from repro.core.banditpam import BanditPAM
    est = BanditPAM(3, seed=0, reuse="pic")
    cold = est.fit(blobs)
    report = fit_guard.fit(est, blobs, warm_start=cold.medoids)
    assert report.dispatches_by_phase == expected_dispatches(
        report, warm=True)
    assert "build" not in report.dispatches_by_phase
    assert report.medoids.tolist() == cold.medoids.tolist()


def test_transfer_guard_fit_batch(fit_guard, blobs):
    """The batched multi-fit path under transfer_guard("disallow"):
    staging is spanned by host_stage, ledgers leave in ONE host_read,
    and the whole batch costs {"build": 1, "swap": 1} dispatches."""
    from repro.core.banditpam import BanditPAM
    est = BanditPAM(3, seed=0, reuse="pic")
    # ragged lane sizes exercise the padded staging path
    datasets = [blobs, blobs[:150], blobs[:97]]
    batch = fit_guard.fit_batch(est, datasets, seeds=[0, 1, 2])
    assert batch.dispatches_by_phase == {"build": 1, "swap": 1}
    # per-fit parity with the single-fit path still holds guarded:
    # medoids to the bit; the final loss reduction on a ragged (padded)
    # lane is allowed a last-bit difference (test_multifit contract)
    solo = BanditPAM(3, seed=1, reuse="pic").fit(blobs[:150])
    assert batch[1].medoids.tolist() == solo.medoids.tolist()
    np.testing.assert_allclose(batch[1].loss, solo.loss, rtol=1e-5)


def test_trace_guard_actually_guards(trace_guard):
    import jax.numpy as jnp
    x = jnp.arange(4)
    with trace_guard():
        with pytest.raises(Exception, match="[Dd]isallow"):
            x * 2  # implicit host→device upload of the Python scalar


def test_host_read_is_sanctioned(trace_guard):
    import jax.numpy as jnp
    from repro.core.engine import host_read, host_stage
    with trace_guard():
        with host_stage("test staging"):
            x = jnp.asarray(np.arange(4.0, dtype=np.float32))
        y = x + x
        out = host_read((y, y.sum()))
    assert out[0].tolist() == [0.0, 2.0, 4.0, 6.0]
    with pytest.raises(ValueError):
        with host_stage(""):
            pass
