"""StatsBackend engine: pallas/jnp full-fit parity, the fused
device-resident driver (single-jit BUILD, fused SWAP steps), re-entrant
fits, and the backend plumbing through the KMedoids facade."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KMedoids
from repro.core import BanditPAM, datasets
from repro.core.adaptive import adaptive_search
from repro.core.banditpam import _build_fused
from repro.core.engine import (available_stats_backends,
                               resolve_stats_backend)


def _ledger(rep):
    return (rep.medoids.tolist(), rep.distance_evals, rep.cached_evals,
            dict(rep.evals_by_phase), rep.n_swaps)


# ---------------------------------------------------------------------------
# Backend parity: pallas and jnp must produce identical fits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric,reuse", [("l2", "none"), ("l2", "pic"),
                                          ("l1", "none")])
def test_backend_parity_full_fit(metric, reuse):
    """Acceptance: backend="pallas" and backend="jnp" give identical
    medoids, loss, and fresh/cached ledger on tier-1 problem sizes."""
    data = datasets.mnist_like(300, seed=7)
    a = BanditPAM(3, metric=metric, seed=0, reuse=reuse,
                  backend="jnp").fit(data)
    b = BanditPAM(3, metric=metric, seed=0, reuse=reuse,
                  backend="pallas").fit(data)
    assert a.medoids.tolist() == b.medoids.tolist()
    assert b.loss == pytest.approx(a.loss, rel=1e-6)
    assert _ledger(a) == _ledger(b)


@pytest.mark.parametrize("reuse", ["none", "pic"])
def test_backend_parity_with_leader_baseline(reuse):
    """The differenced-CI elimination now carries a deterministic
    tie-break (adaptive.LEAD_TIE_REL margin + the leader excluded from
    its own test), so ~1e-6 kernel-vs-jnp distance deltas can no longer
    flip kills that used to sit at exact fp ties — leader-mode ledgers
    compare EXACTLY across stats backends, like baseline="none" always
    did."""
    data = datasets.mnist_like(300, seed=3)
    a = BanditPAM(3, metric="l2", seed=1, baseline="leader", reuse=reuse,
                  backend="jnp").fit(data)
    b = BanditPAM(3, metric="l2", seed=1, baseline="leader", reuse=reuse,
                  backend="pallas").fit(data)
    assert a.medoids.tolist() == b.medoids.tolist()
    assert b.loss == pytest.approx(a.loss, rel=1e-6)
    assert _ledger(a) == _ledger(b)   # incl. medoids + itemised phases


def test_backend_registry_and_resolution():
    assert {"jnp", "pallas"} <= set(available_stats_backends())
    assert resolve_stats_backend("jnp", "l2") == "jnp"
    assert resolve_stats_backend("pallas", "l2") == "pallas"
    # auto never picks interpret-mode pallas on CPU
    if jax.default_backend() == "cpu":
        assert resolve_stats_backend("auto", "l2") == "jnp"
    with pytest.raises(KeyError):
        resolve_stats_backend("bogus", "l2")
    with pytest.raises(ValueError):
        # no kernel for the precomputed lookup metric
        resolve_stats_backend("pallas", "precomputed")


# ---------------------------------------------------------------------------
# Fused driver: single-jit BUILD, fused-vs-stepped equivalence
# ---------------------------------------------------------------------------

def test_build_is_single_jit_entry():
    """The whole BUILD phase is one dispatch of one traced computation:
    a second fit with the same configuration adds no new traces."""
    data = datasets.mnist_like(300, seed=5)
    est = BanditPAM(3, metric="l2", seed=0)
    est.fit(data)
    before = _build_fused._cache_size()
    est.fit(data)
    assert _build_fused._cache_size() == before


@pytest.mark.parametrize("reuse", ["none", "pic"])
def test_fused_matches_stepped(reuse):
    """The fused device-resident driver and the host-orchestrated stepped
    baseline are the same algorithm: identical medoids and ledger."""
    data = datasets.mnist_like(400, seed=3)
    a = BanditPAM(4, metric="l2", seed=1, reuse=reuse, fused=True).fit(data)
    b = BanditPAM(4, metric="l2", seed=1, reuse=reuse, fused=False).fit(data)
    assert _ledger(a) == _ledger(b)
    assert a.loss == pytest.approx(b.loss, rel=1e-6)


def test_wall_by_phase_reported():
    data = datasets.mnist_like(300, seed=0)
    b = BanditPAM(3, metric="l2", seed=0).fit(data)
    assert set(b.wall_by_phase) == {"build", "swap"}
    assert all(v > 0 for v in b.wall_by_phase.values())


# ---------------------------------------------------------------------------
# Re-entrancy: per-fit state lives on FitContext, not the instance
# ---------------------------------------------------------------------------

def test_fit_is_reentrant_same_instance():
    """Refitting the same estimator must match a fresh instance exactly —
    no per-fit state (PIC cache, permutation, warm block) may leak."""
    data = datasets.mnist_like(300, seed=13)
    est = BanditPAM(3, metric="l2", seed=0, reuse="pic")
    first = est.fit(data)
    second = est.fit(data)
    fresh = BanditPAM(3, metric="l2", seed=0, reuse="pic").fit(data)
    assert _ledger(first) == _ledger(second) == _ledger(fresh)
    for attr in ("_pic", "_perm", "_dwarm", "_free_rounds"):
        assert not hasattr(est, attr)


def test_fit_is_reentrant_across_shapes():
    """A second fit on a different n must size its own context (the old
    instance-resident cache would have crashed or served stale columns)."""
    est = BanditPAM(3, metric="l2", seed=0, reuse="pic")
    a = est.fit(datasets.mnist_like(300, seed=1))
    b = est.fit(datasets.mnist_like(450, seed=2))
    fresh_b = BanditPAM(3, metric="l2", seed=0,
                        reuse="pic").fit(datasets.mnist_like(450, seed=2))
    assert _ledger(b) == _ledger(fresh_b)
    assert a.medoids.max() < 300 and b.medoids.max() < 450


def test_no_precomputed_state_needed_before_fit():
    """Pre-fit instances are plain configuration (no crashing accessors)."""
    est = BanditPAM(3, metric="l2", seed=0, reuse="pic")
    assert est.reuse == "pic"
    assert not hasattr(est, "_cache_view")


# ---------------------------------------------------------------------------
# adaptive_search aux threading (the PIC write-through carry)
# ---------------------------------------------------------------------------

def test_adaptive_search_threads_aux():
    n = 64
    rng = np.random.default_rng(0)
    mu = jnp.asarray(rng.uniform(0.0, 1.0, size=n).astype(np.float32))

    def stats_fn(ref_idx, w, lead, rnd, aux):
        g = mu[:, None] * jnp.ones_like(w)[None, :] * w[None, :]
        return (jnp.sum(g, 1), jnp.sum(g * g, 1), g @ g[lead],
                aux + jnp.int32(1))

    sr = adaptive_search(jax.random.PRNGKey(0), stats_fn=stats_fn,
                         exact_fn=lambda: mu, n_arms=n, n_ref=n,
                         batch_size=16, aux_init=jnp.int32(0))
    assert int(sr.aux) == int(sr.rounds)
    assert int(sr.best) == int(jnp.argmin(mu))


# ---------------------------------------------------------------------------
# Facade plumbing
# ---------------------------------------------------------------------------

def test_kmedoids_backend_parity():
    data = datasets.mnist_like(300, seed=7)
    a = KMedoids(3, solver="banditpam", metric="l2", seed=0,
                 backend="jnp").fit(data)
    b = KMedoids(3, solver="banditpam", metric="l2", seed=0,
                 backend="pallas").fit(data)
    assert a.medoids_.tolist() == b.medoids_.tolist()
    assert a.report_.ledger() == b.report_.ledger()
    assert np.array_equal(a.labels_, b.labels_)


def test_kmedoids_backend_rejected_for_non_bandit_solver():
    data = datasets.mnist_like(60, seed=0)
    with pytest.raises(ValueError):
        KMedoids(3, solver="pam", metric="l2", backend="pallas").fit(data)
    # the default "auto" stays valid for every solver
    KMedoids(3, solver="pam", metric="l2").fit(data)
