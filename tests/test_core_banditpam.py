"""Core behaviour: BanditPAM tracks PAM's trajectory (Theorems 1-2 claims)."""
import pytest

from repro.core import BanditPAM, pam, clara, clarans, voronoi_iteration
from repro.core import datasets
import jax.numpy as jnp


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine"])
def test_banditpam_matches_pam_medoids(metric):
    data = datasets.mnist_like(500, seed=7)
    p = pam(data, k=3, metric=metric)
    b = BanditPAM(k=3, metric=metric, seed=0).fit(data)
    assert sorted(p.medoids) == sorted(b.medoids)
    assert b.loss == pytest.approx(p.loss, rel=1e-5)


@pytest.mark.parametrize("sampling", ["permutation", "replacement"])
@pytest.mark.parametrize("baseline", ["none", "leader"])
def test_modes_match_pam(sampling, baseline):
    data = datasets.mnist_like(400, seed=3)
    p = pam(data, k=4, metric="l2")
    b = BanditPAM(k=4, metric="l2", seed=1, sampling=sampling,
                  baseline=baseline).fit(data)
    assert sorted(p.medoids) == sorted(b.medoids)


def test_agreement_rate_across_seeds():
    """Theorem 2: same medoids as PAM with probability 1 - o(1)."""
    agree = 0
    for s in range(8):
        data = datasets.mnist_like(300, seed=20 + s)
        p = pam(data, k=3, metric="l2")
        b = BanditPAM(k=3, metric="l2", seed=s).fit(data)
        agree += sorted(p.medoids) == sorted(b.medoids)
    assert agree >= 7  # paper: "almost all cases"


def test_loss_monotone_during_swaps():
    data = datasets.mnist_like(600, seed=5)
    b = BanditPAM(k=4, metric="l2", seed=0).fit(data)
    losses = [h[2] for h in b.swap_history]
    assert all(l2 < l1 for l1, l2 in zip(losses, losses[1:])) or len(losses) <= 1
    assert b.converged


def test_medoids_are_data_points_and_distinct():
    data = datasets.scrna_like(300, seed=0)
    b = BanditPAM(k=5, metric="l1", seed=0).fit(data)
    assert len(set(b.medoids.tolist())) == 5
    assert all(0 <= m < 300 for m in b.medoids)


def test_eval_count_well_below_exhaustive_at_moderate_n():
    n = 2000
    data = datasets.mnist_like(n, seed=1)
    b = BanditPAM(k=5, metric="l2", seed=0).fit(data)
    iters = 5 + b.n_swaps + 1
    # PAM/FastPAM1 pays >= n^2 per iteration; require a real reduction.
    assert b.distance_evals / iters < 0.5 * n * n


def test_baseline_variance_reduction_helps():
    data = datasets.mnist_like(1500, seed=2)
    b_raw = BanditPAM(k=5, metric="l2", seed=0, baseline="none").fit(data)
    b_vr = BanditPAM(k=5, metric="l2", seed=0, baseline="leader").fit(data)
    assert sorted(b_raw.medoids) == sorted(b_vr.medoids)
    assert b_vr.distance_evals < b_raw.distance_evals


def test_quality_vs_fast_baselines():
    """Fig 1a: BanditPAM (== PAM) loss should be <= baseline algorithms."""
    data = datasets.mnist_like(400, seed=11)
    b = BanditPAM(k=5, metric="l2", seed=0).fit(data)
    v = voronoi_iteration(data, k=5, metric="l2", seed=0)
    c = clarans(data, k=5, metric="l2", seed=0, max_neighbors=100)
    cl = clara(data, k=5, metric="l2", seed=0)
    assert b.loss <= v.loss * 1.001
    assert b.loss <= c.loss * 1.001
    assert b.loss <= cl.loss * 1.001


def test_arbitrary_dissimilarity_registry():
    """k-medoids supports arbitrary (even asymmetric) dissimilarities."""
    from repro.core import register_metric

    def asym(x, y):
        d = jnp.abs(x[:, None, :] - y[None, :, :]).sum(-1)
        return d + 0.1 * (x.sum(-1)[:, None] - y.sum(-1)[None, :])

    register_metric("asym_test", asym)
    data = datasets.hoc4_like(200, seed=0)
    p = pam(data, k=2, metric="asym_test")
    b = BanditPAM(k=2, metric="asym_test", seed=0).fit(data)
    assert sorted(p.medoids) == sorted(b.medoids)
