"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles (kernels run in interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

METRICS = ["l2", "l2sq", "l1", "cosine"]
SHAPES = [(7, 5, 3), (128, 128, 128), (130, 100, 17), (256, 100, 784),
          (64, 300, 129)]  # (m, r, d) incl. unaligned + paper-like dims


def _data(m, r, d, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, d)).astype(dtype)
    y = rng.standard_normal((r, d)).astype(dtype)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("shape", SHAPES)
def test_pairwise_kernel_matches_ref(metric, shape):
    m, r, d = shape
    x, y = _data(m, r, d)
    got = ops.pairwise_distance(x, y, metric, interpret=True)
    want = ref.pairwise_ref(x, y, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_feature_split_matches_ref(metric):
    """D past the per-tile budget is split into dk-chunks whose additive
    cores accumulate exactly (the promise in kernels/pairwise.py)."""
    m, r, d = 48, 56, 700         # d > dk forces 3 chunks (256+256+188pad)
    x, y = _data(m, r, d, seed=11)
    got = ops.pairwise_distance(x, y, metric, dk=256, interpret=True)
    want = ref.pairwise_ref(x, y, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("metric", ["l2sq", "cosine"])
def test_pairwise_feature_split_default_budget(metric):
    """Past the default DK_MAX budget the split engages automatically."""
    m, r, d = 16, 24, ops.DK_MAX + 256
    x, y = _data(m, r, d, seed=12)
    got = ops.pairwise_distance(x, y, metric, interpret=True)
    want = ref.pairwise_ref(x, y, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


def test_pairwise_split_rejects_unaligned_dk():
    x, y = _data(8, 8, 300)
    with pytest.raises(ValueError):
        ops.pairwise_distance(x, y, "l2", dk=200, interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_kernel_dtypes(dtype):
    x, y = _data(64, 64, 64)
    got = ops.pairwise_distance(x.astype(dtype), y.astype(dtype), "l2sq",
                                interpret=True)
    want = ref.pairwise_ref(x.astype(dtype).astype(jnp.float32),
                            y.astype(dtype).astype(jnp.float32), "l2sq")
    assert got.dtype == jnp.float32  # f32 accumulation regardless of input
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("m,b,d", [(64, 100, 32), (300, 100, 784), (128, 37, 50)])
def test_build_g_kernel_matches_ref(metric, m, b, d):
    x, y = _data(m, b, d, seed=1)
    rng = np.random.default_rng(2)
    dnear = jnp.asarray(
        np.where(rng.uniform(size=b) < 0.2, np.inf,
                 rng.uniform(0.5, 3.0, size=b)).astype(np.float32))
    w = jnp.asarray((rng.uniform(size=b) < 0.9).astype(np.float32))
    lead_g_full, _ = ref.build_g_ref(x, y, dnear, w, metric)  # [m]
    lead = 3
    # leader row of g values (w-masked), as the driver would provide
    dl = ref.pairwise_ref(x[lead:lead + 1], y, metric)[0]
    gl = jnp.where(jnp.isinf(dnear), dl, jnp.minimum(dl - dnear, 0.0)) * w
    sums, sq, cross = ops.build_g_stats(x, y, dnear, w, gl, metric=metric,
                                        interpret=True)
    want_sums, want_sq = ref.build_g_ref(x, y, dnear, w, metric)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(want_sums),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(want_sq),
                               rtol=2e-4, atol=5e-3)
    # cross vs dense oracle
    dxy = ref.pairwise_ref(x, y, metric)
    g = jnp.where(jnp.isinf(dnear)[None, :], dxy,
                  jnp.minimum(dxy - dnear[None, :], 0.0)) * w[None, :]
    np.testing.assert_allclose(np.asarray(cross), np.asarray(g @ gl),
                               rtol=2e-4, atol=5e-3)


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("m,b,d,k", [(64, 100, 32, 3), (200, 100, 784, 5),
                                     (128, 64, 20, 10)])
def test_swap_g_kernel_matches_ref(metric, m, b, d, k):
    x, y = _data(m, b, d, seed=3)
    rng = np.random.default_rng(4)
    d1 = jnp.asarray(rng.uniform(0.1, 2.0, size=b).astype(np.float32))
    d2 = jnp.asarray((np.asarray(d1) + rng.uniform(0.1, 2.0, size=b)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, size=b).astype(np.int32))
    w = jnp.asarray((rng.uniform(size=b) < 0.9).astype(np.float32))
    sums, sq, cross = ops.swap_g_stats(x, y, d1, d2, assign, w, k,
                                       metric=metric, interpret=True)
    want_sums, want_sq = ref.swap_g_ref(x, y, d1, d2, assign, w, k, metric)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(want_sums),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(want_sq),
                               rtol=2e-4, atol=5e-3)


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("m,b,d,k", [(64, 100, 32, 3), (130, 64, 20, 10)])
def test_swap_g_cached_kernel_matches_fresh(metric, m, b, d, k):
    """PIC warm path: stats from a cached distance block must equal the
    fused fresh-distance kernel (and thus the Eq. 12 oracle)."""
    x, y = _data(m, b, d, seed=7)
    rng = np.random.default_rng(8)
    d1 = jnp.asarray(rng.uniform(0.1, 2.0, size=b).astype(np.float32))
    d2 = jnp.asarray((np.asarray(d1) + rng.uniform(0.1, 2.0, size=b)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, size=b).astype(np.int32))
    w = jnp.asarray((rng.uniform(size=b) < 0.9).astype(np.float32))
    gl = jnp.asarray(rng.standard_normal(b).astype(np.float32))
    dxy = ref.pairwise_ref(x, y, metric)  # the "resident cache block"
    want = ops.swap_g_stats(x, y, d1, d2, assign, w, k, lead_g=gl,
                            metric=metric, interpret=True)
    got = ops.swap_g_stats_cached(dxy, d1, d2, assign, w, k, lead_g=gl,
                                  interpret=True)
    for g, wnt in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wnt),
                                   rtol=2e-4, atol=5e-3)


def test_swap_g_cached_chunks_capped_cache_width():
    """The cache-served kernel must accept the full capped PIC ring width
    as one batch: past ``CACHE_B_MAX`` the reference axis is split into
    additive chunks whose accumulated stats equal the single-call
    result (this is the tile the carried-statistic repair feeds it)."""
    m, d, k = 64, 16, 3
    b = ops.CACHE_B_MAX + 300          # forces the chunked path
    rng = np.random.default_rng(9)
    x, y = _data(m, b, d, seed=9)
    d1 = jnp.asarray(rng.uniform(0.1, 2.0, size=b).astype(np.float32))
    d2 = jnp.asarray((np.asarray(d1)
                      + rng.uniform(0.1, 2.0, size=b)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, size=b).astype(np.int32))
    w = jnp.asarray((rng.uniform(size=b) < 0.9).astype(np.float32))
    gl = jnp.asarray(rng.standard_normal(b).astype(np.float32))
    dxy = ref.pairwise_ref(x, y, "l2")
    got = ops.swap_g_stats_cached(dxy, d1, d2, assign, w, k, lead_g=gl,
                                  interpret=True)
    want_s, want_q = ref.swap_g_ref(x, y, d1, d2, assign, w, k, "l2")
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want_s),
                               rtol=2e-4, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want_q),
                               rtol=2e-4, atol=5e-2)


@pytest.mark.parametrize("metric", METRICS)
def test_kernel_stats_parity_ragged_shapes(metric):
    """Kernel/jnp stats parity when none of n, B, k is a 128 multiple —
    the padding paths of every fused kernel against the Eq. 6/12 oracles
    (all MXU metrics and l1)."""
    m, b, d, k = 203, 77, 131, 7
    x, y = _data(m, b, d, seed=21)
    rng = np.random.default_rng(22)
    w = jnp.asarray((rng.uniform(size=b) < 0.85).astype(np.float32))
    # BUILD
    dnear = jnp.asarray(
        np.where(rng.uniform(size=b) < 0.3, np.inf,
                 rng.uniform(0.5, 3.0, size=b)).astype(np.float32))
    sums, sq, _ = ops.build_g_stats(x, y, dnear, w, metric=metric,
                                    interpret=True)
    want_sums, want_sq = ref.build_g_ref(x, y, dnear, w, metric)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(want_sums),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(want_sq),
                               rtol=2e-4, atol=5e-3)
    # SWAP fresh + cache-served, sharing one oracle
    d1 = jnp.asarray(rng.uniform(0.1, 2.0, size=b).astype(np.float32))
    d2 = jnp.asarray((np.asarray(d1)
                      + rng.uniform(0.1, 2.0, size=b)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, size=b).astype(np.int32))
    s_f, q_f, _ = ops.swap_g_stats(x, y, d1, d2, assign, w, k,
                                   metric=metric, interpret=True)
    want_s, want_q = ref.swap_g_ref(x, y, d1, d2, assign, w, k, metric)
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(want_s),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(q_f), np.asarray(want_q),
                               rtol=2e-4, atol=5e-3)
    dxy = ref.pairwise_ref(x, y, metric)
    s_c, q_c, _ = ops.swap_g_stats_cached(dxy, d1, d2, assign, w, k,
                                          interpret=True)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(want_s),
                               rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(q_c), np.asarray(want_q),
                               rtol=2e-4, atol=5e-3)


def test_swap_g_cross_term():
    m, b, d, k = 64, 100, 16, 4
    x, y = _data(m, b, d, seed=5)
    rng = np.random.default_rng(6)
    d1 = jnp.asarray(rng.uniform(0.1, 2.0, size=b).astype(np.float32))
    d2 = jnp.asarray((np.asarray(d1) + rng.uniform(0.1, 2.0, size=b)).astype(np.float32))
    assign = jnp.asarray(rng.integers(0, k, size=b).astype(np.int32))
    w = jnp.ones((b,), jnp.float32)
    # leader = arm (m_l=1, x_l=7)
    dxy = ref.pairwise_ref(x, y, "l2")
    in_c1 = assign == 1
    gl = jnp.where(in_c1, -d1 + jnp.minimum(d2, dxy[7]),
                   -d1 + jnp.minimum(d1, dxy[7]))
    _, _, cross = ops.swap_g_stats(x, y, d1, d2, assign, w, k, lead_g=gl,
                                   metric="l2", interpret=True)
    # dense oracle
    in_cm = np.asarray(assign)[None, :] == np.arange(k)[:, None]
    g = np.where(in_cm[:, None, :],
                 np.asarray(-d1)[None, None, :] + np.minimum(np.asarray(d2)[None, None, :], np.asarray(dxy)[None]),
                 np.asarray(-d1)[None, None, :] + np.minimum(np.asarray(d1)[None, None, :], np.asarray(dxy)[None]))
    want = (g * np.asarray(gl)[None, None, :]).sum(-1)
    np.testing.assert_allclose(np.asarray(cross), want, rtol=2e-4, atol=5e-3)


def test_install_reroutes_core_metrics():
    from repro.core import distances
    orig = distances.get_metric("l2sq")
    try:
        ops.install(("l2sq",))
        x, y = _data(32, 16, 8)
        got = distances.get_metric("l2sq")(x, y)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.pairwise_ref(x, y, "l2sq")),
                                   rtol=1e-4, atol=1e-4)
    finally:
        distances.register_metric("l2sq", orig)
