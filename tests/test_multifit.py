"""Differential harness for the batched multi-fit engine.

The contract under test (ISSUE 6): ``fit_batch`` on a ``[B, n, d]`` batch
must reproduce the loop of single ``fit`` calls BIT-identically per fit —
same medoids (same order), same loss bits, same fresh/cached ledger, same
swap history — for the same per-fit seed, on both stats backends, with and
without the BanditPAM++ PIC cache, including ragged per-fit n via padding
masks.  The only sanctioned divergence is the final LOSS reduction on a
ragged batch: the masked sum over ``[n_max]`` may split the f32 reduction
tree differently from the plain sum over ``[n_i]`` (~1 ulp) — medoids,
integer ledgers, and swap decisions must still match exactly, so the
ragged tests pin those to the bit and the loss to a tight allclose.

Also locks down: one jit per phase (measured ``dispatches_by_phase``),
B=1 degeneracy, per-fit seed independence (batch-permutation
equivariance), and the golden ledger fixtures in
``tests/fixtures/ledgers.json`` (regenerate with ``REGEN_GOLDEN=1``).
"""
import json
import os
import pathlib

import numpy as np
import pytest

from repro.api import KMedoids
from repro.core import BanditPAM, datasets

K = 3
FIXTURE = pathlib.Path(__file__).parent / "fixtures" / "ledgers.json"


def _make_batch(ns, seed0=100):
    return [np.asarray(datasets.hoc4_like(n, seed=seed0 + i), np.float32)
            for i, n in enumerate(ns)]


def _single_fits(Xs, seeds, *, metric, reuse, backend, **kw):
    return [BanditPAM(K, metric=metric, seed=s, reuse=reuse,
                      backend=backend, **kw).fit(X)
            for X, s in zip(Xs, seeds)]


def _assert_fit_equal(got, want, *, exact_loss=True, tag=""):
    """Bit-parity between one lane of a batch report and a single fit."""
    assert np.array_equal(np.asarray(got.medoids),
                          np.asarray(want.medoids)), tag
    if exact_loss:
        assert float(got.loss) == float(want.loss), tag
    else:
        np.testing.assert_allclose(got.loss, want.loss, rtol=1e-5,
                                   err_msg=tag)
    assert got.distance_evals == want.distance_evals, tag
    assert got.cached_evals == want.cached_evals, tag
    assert got.evals_by_phase == want.evals_by_phase, tag
    assert got.n_swaps == want.n_swaps, tag
    assert got.converged == want.converged, tag
    assert got.build_rounds == want.build_rounds, tag
    assert len(got.swap_history) == len(want.swap_history), tag
    for (go, gn, gl), (wo, wn, wl) in zip(got.swap_history,
                                          want.swap_history):
        assert (go, gn) == (wo, wn), tag
        if exact_loss:
            assert float(gl) == float(wl), tag
        else:
            np.testing.assert_allclose(gl, wl, rtol=1e-5, err_msg=tag)


# ---------------------------------------------------------------------------
# Tentpole invariant: fit_batch == loop of fit, to the bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,reuse", [
    ("jnp", "none"), ("jnp", "pic"),
    ("pallas", "none"), ("pallas", "pic"),
])
@pytest.mark.parametrize("metric", ["l1", "l2"])
def test_batch_matches_loop_uniform(backend, reuse, metric):
    """Uniform n: FULL bit-parity, loss bits included, on both backends."""
    n = 40 if backend == "pallas" else 60
    Xs = _make_batch([n, n, n])
    seeds = [1, 2, 3]
    est = BanditPAM(K, metric=metric, seed=0, reuse=reuse, backend=backend)
    batch = est.fit_batch(Xs, seeds=seeds)
    singles = _single_fits(Xs, seeds, metric=metric, reuse=reuse,
                           backend=backend)
    assert len(batch) == 3
    for i, want in enumerate(singles):
        _assert_fit_equal(batch[i], want, exact_loss=True,
                          tag=f"fit {i} ({backend}/{reuse}/{metric})")
    if reuse == "pic":
        assert all(r.cached_evals > 0 for r in batch)


@pytest.mark.parametrize("backend,reuse", [
    ("jnp", "none"), ("jnp", "pic"),
    ("pallas", "none"), ("pallas", "pic"),
])
def test_batch_matches_loop_ragged(backend, reuse):
    """Ragged per-fit n: medoids, integer ledgers, swap decisions, and
    build rounds stay EXACT; only the final loss reduction is allowed the
    ~1-ulp masked-sum drift (see module docstring)."""
    ns = [24, 40, 17] if backend == "pallas" else [47, 60, 33]
    Xs = _make_batch(ns)
    seeds = [7, 8, 9]
    est = BanditPAM(K, metric="l1", seed=0, reuse=reuse, backend=backend)
    batch = est.fit_batch(Xs, seeds=seeds)
    singles = _single_fits(Xs, seeds, metric="l1", reuse=reuse,
                           backend=backend)
    for i, want in enumerate(singles):
        _assert_fit_equal(batch[i], want, exact_loss=False,
                          tag=f"fit {i} n={ns[i]} ({backend}/{reuse})")


def test_batch_of_one_degenerates_to_single_fit():
    X = _make_batch([55])[0]
    batch = BanditPAM(K, metric="l2", seed=0).fit_batch([X], seeds=[5])
    single = BanditPAM(K, metric="l2", seed=5).fit(X)
    assert len(batch) == 1
    _assert_fit_equal(batch[0], single, exact_loss=True, tag="B=1")
    assert batch.dispatches_by_phase == {"build": 1, "swap": 1}


def test_one_jit_per_phase_at_b8():
    """The acceptance gate: B >= 8 fits compile to ONE dispatch per phase
    (measured by counted_dispatch, not inferred)."""
    Xs = _make_batch([48] * 8)
    batch = BanditPAM(K, metric="l1", seed=0).fit_batch(
        Xs, seeds=list(range(8)))
    assert batch.dispatches_by_phase == {"build": 1, "swap": 1}
    assert len(batch) == 8
    assert set(batch.wall_by_phase) == {"build", "swap"}


def test_per_fit_seed_independence_batch_permutation():
    """Fits are independent: permuting (dataset, seed) pairs permutes the
    per-fit results bit-for-bit — no cross-lane leakage through the batch
    axis, the RNG chains, or the shared PIC ring."""
    Xs = _make_batch([50, 50, 50, 50])
    seeds = [11, 12, 13, 14]
    perm = [2, 0, 3, 1]
    for reuse in ("none", "pic"):
        est = BanditPAM(K, metric="l1", seed=0, reuse=reuse)
        a = est.fit_batch(Xs, seeds=seeds)
        b = est.fit_batch([Xs[p] for p in perm], seeds=[seeds[p] for p in perm])
        for j, p in enumerate(perm):
            _assert_fit_equal(b[j], a[p], exact_loss=True,
                              tag=f"lane {j}<-{p} ({reuse})")


def test_same_seed_different_data_diverges():
    """Sharing one seed across the batch must NOT share outcomes — the
    data, not the RNG chain, drives each fit."""
    Xs = _make_batch([50, 50], seed0=300)
    batch = BanditPAM(K, metric="l1", seed=4).fit_batch(Xs)  # seeds=None
    assert not np.array_equal(np.asarray(batch[0].medoids),
                              np.asarray(batch[1].medoids)) \
        or float(batch[0].loss) != float(batch[1].loss)


# ---------------------------------------------------------------------------
# Facade: KMedoids.fit_batch
# ---------------------------------------------------------------------------

def test_facade_fit_batch_labels_and_state():
    ns = [47, 60, 33]
    Xs = _make_batch(ns)
    est = KMedoids(K, solver="banditpam_pp", metric="l1", seed=0,
                   backend="jnp")
    rep = est.fit_batch(Xs, seeds=[1, 2, 3])
    assert rep.dispatches_by_phase == {"build": 1, "swap": 1}
    assert rep.labels.shape == (3, max(ns))
    assert rep.solver == "banditpam_pp" and rep.metric == "l1"
    # labels on the VALID rows match the single-fit facade labels
    for i, (X, n) in enumerate(zip(Xs, ns)):
        single = KMedoids(K, solver="banditpam_pp", metric="l1",
                          seed=1 + i, backend="jnp").fit(X)
        assert np.array_equal(rep.labels[i, :n], single.labels_)
        assert np.array_equal(rep.medoids[i], single.medoids_)
    # a batch fit must NOT install single-fit state
    assert est.report_ is None and est.medoids_ is None
    with pytest.raises(ValueError, match="not fitted"):
        est.predict(Xs[0])


def test_facade_rejects_unbatchable_configs():
    Xs = _make_batch([30, 30])
    with pytest.raises(ValueError, match="no batched entrypoint"):
        KMedoids(K, solver="pam").fit_batch(Xs)
    with pytest.raises(KeyError, match="unknown solver"):
        KMedoids(K, solver="nope").fit_batch(Xs)
    with pytest.raises(ValueError, match="precomputed"):
        KMedoids(K, metric="precomputed").fit_batch(Xs)
    with pytest.raises(ValueError, match='sampling="permutation"'):
        BanditPAM(K, sampling="uniform").fit_batch(Xs)
    with pytest.raises(ValueError, match="cache_cols"):
        BanditPAM(K, cache_cols=32).fit_batch(Xs)
    with pytest.raises(ValueError, match="seeds"):
        BanditPAM(K).fit_batch(Xs, seeds=[1])
    with pytest.raises(ValueError, match="feature dim"):
        BanditPAM(K).fit_batch([Xs[0], Xs[1][:, :2]])
    with pytest.raises(ValueError, match="n > k"):
        BanditPAM(K).fit_batch([Xs[0], Xs[1][:K]])


# ---------------------------------------------------------------------------
# Golden ledgers: tests/fixtures/ledgers.json pins the exact medoids, loss
# bits, and fresh/cached ledger of canonical configs.  ANY bit drift in the
# sampling layout, CI maths, or accept rule fails here first.  Regenerate
# (after an INTENDED change, with the diff reviewed) via:
#     REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_multifit.py -k golden
# ---------------------------------------------------------------------------

GOLDEN_CONFIGS = {
    "l1_none": dict(metric="l1", reuse="none"),
    "l1_pic": dict(metric="l1", reuse="pic"),
    "l2_pic_leader": dict(metric="l2", reuse="pic", baseline="leader"),
}


def _golden_record(cfg):
    Xs = _make_batch([47, 60, 33], seed0=200)
    batch = BanditPAM(K, seed=0, backend="jnp", **cfg).fit_batch(
        Xs, seeds=[1, 2, 3])
    return [{
        "medoids": np.asarray(r.medoids).tolist(),
        # float().hex() is exact — a single-ulp drift changes the string
        "loss_hex": float(r.loss).hex(),
        "distance_evals": r.distance_evals,
        "cached_evals": r.cached_evals,
        "evals_by_phase": dict(r.evals_by_phase),
        "swap_history": [[o, x, float(l).hex()]
                         for o, x, l in r.swap_history],
        "build_rounds": list(r.build_rounds),
    } for r in batch]


def test_golden_ledgers_bit_stable():
    if os.environ.get("REGEN_GOLDEN"):
        FIXTURE.parent.mkdir(exist_ok=True)
        FIXTURE.write_text(json.dumps(
            {name: _golden_record(cfg)
             for name, cfg in GOLDEN_CONFIGS.items()}, indent=1) + "\n")
        pytest.skip(f"regenerated {FIXTURE}")
    assert FIXTURE.exists(), \
        f"missing {FIXTURE}; regenerate with REGEN_GOLDEN=1"
    golden = json.loads(FIXTURE.read_text())
    assert set(golden) == set(GOLDEN_CONFIGS)
    for name, cfg in GOLDEN_CONFIGS.items():
        got = _golden_record(cfg)
        want = golden[name]
        assert len(got) == len(want), name
        for i, (g, w) in enumerate(zip(got, want)):
            assert g == w, (
                f"golden ledger drift in {name!r} fit {i}:\n"
                f"  got  {json.dumps(g, sort_keys=True)}\n"
                f"  want {json.dumps(w, sort_keys=True)}")
