"""Fault-tolerance substrate: checkpoint/restore, resume, preemption,
straggler detection, elastic re-mesh planning, and the serving layer's
service-state checkpoints (numpy-leaf exactness + mesh restore)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import plan_remesh
from repro.runtime.fault import FaultTolerantLoop, Preemption, StragglerMonitor


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.int32(3)}}


def test_checkpoint_roundtrip_bitexact(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 7, s, extra={"data_step": 7})
    restored, meta = ckpt.restore(str(tmp_path), s)
    assert meta["step"] == 7
    assert meta["extra"]["data_step"] == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_structure_guard(tmp_path):
    ckpt.save(str(tmp_path), 1, _state())
    ckpt.save(str(tmp_path), 5, _state(1))
    assert ckpt.latest_step(str(tmp_path)) == 5
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), {"different": jnp.zeros(3)})


def test_checkpoint_numpy_leaves_keep_dtype_and_bits(tmp_path):
    """Host-state leaves (f64 reservoir keys, i64 counters) must come
    back as numpy with the saved bits — not silently downcast to the
    jax f32 regime like device leaves are."""
    s = {"keys": np.array([1.0 + 1e-12, -np.inf], np.float64),
         "count": np.int64(2**40 + 7),
         "dev": jnp.arange(4, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 1, s)
    restored, _ = ckpt.restore(str(tmp_path), s)
    assert isinstance(restored["keys"], np.ndarray)
    assert restored["keys"].dtype == np.float64
    assert restored["keys"].tobytes() == s["keys"].tobytes()
    assert int(restored["count"]) == 2**40 + 7
    assert isinstance(restored["dev"], jax.Array)


def test_service_state_checkpoint_roundtrip(tmp_path):
    """The MedoidService state tree — medoids, reservoir (pts + f64 A-Res
    keys + stream position = RNG chain position), drift counters —
    round-trips bit-exactly through runtime.checkpoint."""
    from repro.core import datasets
    from repro.serve import MedoidService

    X = datasets.mnist_like(300, seed=0, d=16)
    svc = MedoidService(3, "l2", reservoir_size=64, drift_window=50,
                        request_chunk=128, seed=0).fit(X)
    svc.ingest(datasets.mnist_like(80, seed=1, d=16) + 0.2)
    path = svc.snapshot(str(tmp_path))
    assert os.path.isdir(path)
    svc2 = MedoidService.restore(str(tmp_path))
    a, b = svc._state_tree(), svc2._state_tree()
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        na, nb = np.asarray(la), np.asarray(lb)
        assert na.dtype == nb.dtype
        assert na.tobytes() == nb.tobytes()
    assert svc2.config() == svc.config()


_MESH_RESTORE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import datasets
    from repro.serve import MedoidService

    ckpt_dir = sys.argv[1]
    X = datasets.mnist_like(300, seed=0, d=16)
    svc = MedoidService(4, "l2", reservoir_size=64, drift_window=50,
                        request_chunk=128, seed=0).fit(X)
    svc.ingest(datasets.mnist_like(80, seed=1, d=16) + 0.2)
    svc.snapshot(ckpt_dir)
    q = datasets.mnist_like(32, seed=2, d=16)
    want = svc.predict(q)

    # restore onto a DIFFERENT mesh: medoids sharded over 4 devices
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    shardings = {"medoid_points": NamedSharding(mesh, P("data", None)),
                 "reservoir": {k: None for k in
                               ("pts", "keys", "sidx", "filled", "seen")},
                 "drift": {k: None for k in ("baseline", "sum", "count")},
                 "counters": {k: None for k in
                              ("n_refits", "fresh", "cached")}}
    svc2 = MedoidService.restore(ckpt_dir, shardings=shardings)
    got = svc2.predict(q)
    sharded = len(svc2.medoid_points.sharding.device_set) == 4
    print(json.dumps({"match": bool(np.array_equal(want, got)),
                      "sharded": sharded,
                      "stats_match": svc.stats() == svc2.stats()}))
""")


def test_service_restore_onto_different_mesh(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", _MESH_RESTORE, str(tmp_path)],
        capture_output=True, text=True,
        cwd=str(pathlib.Path(__file__).resolve().parent.parent),
        env=dict(os.environ, PYTHONPATH="src"), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"match": True, "sharded": True, "stats_match": True}


def test_fault_loop_resumes_after_transient_failure(tmp_path):
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        if step == 3 and calls["n"] == 4:      # fail once at step 3
            raise RuntimeError("transient")
        return {"x": state["x"] + 1}, {"loss": 0.0}

    loop = FaultTolerantLoop(str(tmp_path), save_every=2, install_sigterm=False)
    out = loop.run({"x": jnp.float32(0)}, step_fn, n_steps=6)
    assert float(out["x"]) == 6.0              # deterministic replay => exact


def test_fault_loop_preemption_checkpoints(tmp_path):
    loop = FaultTolerantLoop(str(tmp_path), save_every=100, install_sigterm=False)

    def step_fn(state, step):
        if step == 2:
            loop._preempted = True             # simulate SIGTERM delivery
        return {"x": state["x"] + 1}, {}

    with pytest.raises(Preemption):
        loop.run({"x": jnp.float32(0)}, step_fn, n_steps=10)
    assert ckpt.latest_step(str(tmp_path)) == 3
    restored, _ = ckpt.restore(str(tmp_path), {"x": jnp.float32(0)})
    assert float(restored["x"]) == 3.0


def test_restore_or_fast_forwards(tmp_path):
    loop = FaultTolerantLoop(str(tmp_path), save_every=2, install_sigterm=False)
    state = loop.run({"x": jnp.float32(0)},
                     lambda s, i: ({"x": s["x"] + 1}, {}), n_steps=4)
    # new loop instance (fresh process after failure)
    loop2 = FaultTolerantLoop(str(tmp_path), save_every=2, install_sigterm=False)
    restored, start = loop2.restore_or({"x": jnp.float32(0)})
    assert start == 4 and float(restored["x"]) == 4.0


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0)
    for step in range(5):
        for host in range(8):
            mon.record(host, 1.0 if host != 3 else 5.0)
    assert mon.stragglers() == [3]


def test_elastic_plan_remesh():
    p = plan_remesh(512, model_parallel=16, pods=2)
    assert p.shape == (2, 16, 16) and p.dropped_chips == 0
    # lose a host (8 chips): data axis shrinks to the next power of two
    p = plan_remesh(504, model_parallel=16, pods=2)
    assert p.shape[0] == 2 and p.shape[2] == 16
    assert np.prod(p.shape) <= 504
    p = plan_remesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    with pytest.raises(ValueError):
        plan_remesh(8, model_parallel=16)


def test_data_pipeline_determinism_and_resume():
    from repro.configs import get_reduced
    from repro.train import DataPipeline, synthetic_batch

    cfg = get_reduced("qwen3_1_7b")
    b1 = synthetic_batch(cfg, 4, 16, step=5)
    b2 = synthetic_batch(cfg, 4, 16, step=5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    pipe = DataPipeline(cfg, 4, 16)
    for _ in range(3):
        next(pipe)
    st = pipe.state()
    pipe2 = DataPipeline.from_state(cfg, 4, 16, st)
    np.testing.assert_array_equal(np.asarray(next(pipe)["tokens"]),
                                  np.asarray(next(pipe2)["tokens"]))
