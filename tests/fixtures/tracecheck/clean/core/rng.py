"""tracecheck fixture: sanctioned RNG chain (TRC003 negatives)."""

import jax


def _phase_key(seed, tag, step):
    # Sanctioned chain head (config lists `_phase_key`): the one raw
    # PRNGKey, immediately folded into the documented chain.
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ tag), step)


def round_draw(chain, rnd, shard, n):
    # Draws key off the fold_in chain, never a fresh PRNGKey.
    key = jax.random.fold_in(jax.random.fold_in(chain, rnd), shard)
    return jax.random.randint(key, (n,), 0, n)
