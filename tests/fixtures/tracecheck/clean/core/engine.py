"""tracecheck fixture: the contract-conformant forms of each rule.

Every pattern here is the sanctioned counterpart of a bad/ violation —
the corpus must produce ZERO findings under the shipped config.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def good_build(data, *, k):
    # lax.fori_loop, not a Python loop (TRC002 counterpart).
    def body(i, dnear):
        return jnp.minimum(dnear, jnp.sum(jnp.abs(data - data[i]), axis=1))

    init = jnp.full((data.shape[0],), jnp.inf, jnp.float32)
    return jax.lax.fori_loop(0, k, body, init)


@jax.jit
def masked_top2(dmat):
    # Where-mask inside the pass, not at[].set(inf) (TRC005 counterpart).
    a = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    cols = jax.lax.broadcasted_iota(jnp.int32, dmat.shape, 1)
    d2 = jnp.min(jnp.where(cols == a[:, None], jnp.inf, dmat), axis=1)
    return jnp.min(dmat, axis=1), d2, a


def host_driver(data):
    # Host orchestration may sync: not jit-reachable (TRC001 negative).
    d = good_build(jnp.asarray(data, jnp.float32), k=3)
    total = float(np.asarray(d).sum())
    for _ in range(2):  # host loop: TRC002 negative
        total += 1.0
    return total


@jax.jit
def justified(x):
    # Suppression WITH a justification: suppressed, and no TRC000.
    # tracecheck: ignore[TRC001] -- fixture: demonstrates a justified
    # suppression; x is replaced by a static int at every call site.
    return float(x)
