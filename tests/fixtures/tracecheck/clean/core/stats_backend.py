"""tracecheck fixture: collective-free StatsBackend (TRC004 negative)."""

import jax.numpy as jnp


class PartialSumStatsBackend:
    name = "partial"

    def build_stats_from_d(self, dxy, dnear_b, w):
        # Per-shard partial sums only; the distributed layer composes
        # them with its single psum.
        g = jnp.minimum(dxy - dnear_b[None, :], 0.0) * w[None, :]
        return jnp.sum(g, axis=1)
