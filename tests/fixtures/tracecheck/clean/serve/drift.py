"""tracecheck fixture: f64-disciplined host accounting (TRC005 negative)."""

import numpy as np


class Monitor:
    def __init__(self):
        self.sum = np.float64(0.0)
        self.count = np.int64(0)

    def update(self, dmin):
        d = np.asarray(dmin, np.float64).ravel()
        self.sum = np.float64(self.sum + d.sum(dtype=np.float64))
        self.count = np.int64(self.count + d.shape[0])
        return self.sum / np.float64(max(int(self.count), 1))
