"""tracecheck fixture: TRC005 dtype-less conversion in checkpoint restore."""

import jax.numpy as jnp
import numpy as np


def restore_leaf(arr):
    # TRC005: no dtype — an f64 numpy leaf comes back f32.
    return jnp.asarray(arr)


def restore_stat(x):
    # TRC005: astype to f32 breaks the bit-exact round-trip.
    return np.asarray(x, np.float64).astype("float32")
