"""tracecheck fixture: TRC005 at[].set(inf) masking on a streaming path."""

import jax.numpy as jnp


def top2(dmat):
    a = jnp.argmin(dmat, axis=1)
    rows = jnp.arange(dmat.shape[0])
    # TRC005: materializes a full masked copy — the streaming contract
    # is online (min, min2) accumulation.
    masked = dmat.at[rows, a].set(jnp.inf)
    return jnp.min(dmat, axis=1), jnp.min(masked, axis=1), a
