"""tracecheck fixture: TRC003 raw-PRNGKey violations (PR-4 bug shape)."""

import jax


def resample(n, step):
    # TRC003: raw key construction outside a sanctioned chain head —
    # two call sites with equal `step` silently draw identical subsets.
    key = jax.random.PRNGKey(step)
    return jax.random.randint(key, (n,), 0, n)


def draw_inline(n):
    # TRC003: draw keyed directly on a fresh PRNGKey.
    return jax.random.uniform(jax.random.PRNGKey(0), (n,))
