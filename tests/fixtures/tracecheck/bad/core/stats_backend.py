"""tracecheck fixture: TRC004 collective inside a StatsBackend."""

import jax
import jax.numpy as jnp


class ShardedStatsBackend:
    name = "sharded"

    def build_stats_from_d(self, dxy, dnear_b, w):
        g = jnp.minimum(dxy - dnear_b[None, :], 0.0) * w[None, :]
        # TRC004: backends are collective-free by contract; the psum
        # composition point belongs to the distributed layer.
        return jax.lax.psum(jnp.sum(g, axis=1), "data")
