"""tracecheck fixture: TRC001 host syncs + TRC002 loops in traced code.

Never imported — parsed by tests/test_analysis.py as a known-violation
corpus.  The directory shape (bad/core/) puts it in the same rule
scopes as src/repro/core/.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("n",))
def bad_build(data, *, n):
    total = jnp.float32(0.0)
    for i in range(n):                             # TRC002: unrolled loop
        total = total + float(jnp.sum(data[i]))    # TRC001: float() sync
    return np.asarray(total)                       # TRC001: numpy fallback


def loop_body(i, carry):
    return carry + carry.item()                    # TRC001 via fori closure


def run(c0):
    return jax.lax.fori_loop(0, 3, loop_body, c0)


def _step(x):
    return x * 2


def host_driver(data):
    # NOT jit-reachable: host orchestration may sync freely.
    fn = jax.jit(_step)
    out = fn(data)
    while float(out.sum()) < 0.0:                  # host loop: no finding
        out = fn(out)
    return out.item()
