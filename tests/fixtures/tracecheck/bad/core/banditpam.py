"""tracecheck fixture: TRC005 vmap in a batch driver."""

import jax


def _swap_batch(data, meds):
    # TRC005: lane parity contract is lax.map replaying single-fit HLO.
    return jax.vmap(lambda d, m: d[m].sum(axis=-1))(data, meds)
