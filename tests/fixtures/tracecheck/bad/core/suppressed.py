"""tracecheck fixture: TRC000 — suppression without a justification.

The bare ignore below DOES suppress its TRC001 target, but the missing
`-- reason` raises TRC000 instead.
"""

import jax


@jax.jit
def f(x):
    return float(x)  # tracecheck: ignore[TRC001]
