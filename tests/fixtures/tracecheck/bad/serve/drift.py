"""tracecheck fixture: TRC005 f32 round-trip in f64 host accounting."""

import numpy as np


class LeakyDriftMonitor:
    def __init__(self):
        self.sum = np.float64(0.0)

    def update(self, dmin):
        d = np.asarray(dmin, np.float64)
        # TRC005: silently rounds the f64 accumulator to f32.
        self.sum = np.float32(self.sum + d.sum())
        return self.sum
