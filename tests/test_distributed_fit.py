"""Distributed fit on the StatsBackend engine: backend parity (jnp vs
Pallas through the sharded path), uneven-n padding, the facade
round-trip, curator mesh gating, and the sharded-RNG round-collision
regression.

The suite needs a multi-device host.  When this process already exposes
>= 4 devices (CI runs a dedicated step with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), the tests run
in-process; on a single-device host one umbrella test re-runs this file
under 8 simulated CPU devices in a subprocess, so a plain tier-1 run
exercises the sharded path everywhere.  ``REPRO_SKIP_DIST_SUBPROC=1``
disables the umbrella (set by CI's main suite step, whose coverage comes
from the flagged step instead).
"""
import os
import pathlib
import subprocess
import sys

import jax
import numpy as np
import pytest

_MULTI = len(jax.devices()) >= 4

if not _MULTI:

    @pytest.mark.skipif(
        os.environ.get("REPRO_SKIP_DIST_SUBPROC") == "1",
        reason="sharded suite covered by the flagged CI step")
    def test_distributed_suite_under_simulated_devices():
        repo = pathlib.Path(__file__).resolve().parent.parent
        # Inherit the parent environment (JAX_PLATFORMS etc. — without it
        # the child pays minutes of backend probing) and only force the
        # device-count flag + import path.
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "pytest", "-x", "-q", __file__],
            capture_output=True, text=True, cwd=str(repo), timeout=1800,
            env=env)
        assert out.returncode == 0, out.stdout[-4000:] + out.stderr[-2000:]

else:
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.api import KMedoids
    from repro.core import datasets, pam
    from repro.core import distributed as dist
    from repro.core.distributed import (DistributedBanditPAM, MedoidCurator,
                                        default_mesh)
    from repro.core.engine import get_stats_backend

    # Uneven on purpose: 257 is coprime to any simulated device count, so
    # every fit below exercises the padded sharded view.
    N, K, SEED = 257, 3, 0

    @pytest.fixture(scope="module")
    def data():
        return datasets.mnist_like(N, seed=3)

    @pytest.fixture(scope="module")
    def mesh():
        return default_mesh()

    @pytest.fixture(scope="module")
    def fits(data, mesh):
        return {b: DistributedBanditPAM(K, mesh, metric="l2", seed=SEED,
                                        backend=b).fit(data)
                for b in ("jnp", "pallas")}

    # -- backend parity + ledger ----------------------------------------
    def test_backends_produce_identical_medoids_and_loss(fits):
        j, p = fits["jnp"], fits["pallas"]
        assert np.array_equal(np.sort(j.medoids), np.sort(p.medoids))
        assert j.loss == pytest.approx(p.loss, rel=1e-6)

    def test_loss_matches_single_device_tier(fits, data):
        ref = pam(data, K, metric="l2")
        for r in fits.values():
            assert abs(r.loss - ref.loss) / ref.loss < 1e-3

    def test_fit_report_fully_populated(fits):
        for r in fits.values():
            assert r.evals_by_phase["build"] > 0
            assert r.evals_by_phase["swap"] > 0
            assert r.distance_evals == sum(r.evals_by_phase.values())
            assert set(r.wall_by_phase) == {"build", "swap"}
            assert all(v > 0 for v in r.wall_by_phase.values())
            assert r.solver == "banditpam_dist" and r.metric == "l2"
            assert len(r.build_rounds) == K
            assert r.converged

    def test_backend_ledgers_compare_exactly(fits):
        """The leader fp-tie fix (adaptive.LEAD_TIE_REL) makes the
        sharded jnp and Pallas ledgers identical, not just the answer."""
        j, p = fits["jnp"], fits["pallas"]
        assert dict(j.evals_by_phase) == dict(p.evals_by_phase)
        assert j.build_rounds == p.build_rounds

    def test_build_phase_is_single_dispatch(fits):
        """The fused sharded BUILD is ONE jit dispatch for the whole
        phase (fori_loop over the k selections with the shard_map
        inside), not one per selection."""
        for r in fits.values():
            assert r.dispatches_by_phase["build"] == 1
            assert r.dispatches_by_phase["swap"] == r.n_swaps + 1

    # -- sharded PIC cache (reuse="pic") ---------------------------------
    @pytest.fixture(scope="module")
    def pic_fits(data, mesh):
        return {b: DistributedBanditPAM(K, mesh, metric="l2", seed=SEED,
                                        backend=b, reuse="pic").fit(data)
                for b in ("jnp", "pallas")}

    def test_sharded_pic_reports_cached_ledger_split(pic_fits, fits):
        """Acceptance: DistributedBanditPAM(reuse="pic") reports a
        non-zero cached count, the fresh/cached split is itemised, and
        the reuse engine pays measurably fewer fresh evaluations than
        the cache-less sharded fit."""
        for r in pic_fits.values():
            assert r.cached_evals > 0
            assert {"build", "swap", "build_cached",
                    "swap_cached"} <= set(r.evals_by_phase)
            assert r.distance_evals == sum(
                v for ph, v in r.evals_by_phase.items()
                if not ph.endswith("_cached"))
            assert r.cached_evals == sum(
                v for ph, v in r.evals_by_phase.items()
                if ph.endswith("_cached"))
            assert r.distance_evals < fits["jnp"].distance_evals
            assert r.dispatches_by_phase["build"] == 1

    def test_sharded_pic_matches_single_device_answer(pic_fits, data):
        """Sharded-vs-single-device parity: different (equally valid)
        sampling schedules, same exact-PAM answer tier."""
        from repro.core import BanditPAM
        single = BanditPAM(K, metric="l2", seed=SEED, reuse="pic").fit(data)
        for r in pic_fits.values():
            assert sorted(r.medoids.tolist()) == sorted(
                single.medoids.tolist())
            assert r.loss == pytest.approx(single.loss, rel=1e-5)

    def test_sharded_pic_backend_ledgers_compare_exactly(pic_fits):
        j, p = pic_fits["jnp"], pic_fits["pallas"]
        assert np.array_equal(np.sort(j.medoids), np.sort(p.medoids))
        assert dict(j.evals_by_phase) == dict(p.evals_by_phase)

    def test_sharded_pic_tiny_cache_width_recycles_exactly(pic_fits, mesh,
                                                           data):
        """A tiny sharded ring forces recycling: medoids/loss unchanged,
        fresh count rises — the exact-fallback invariant holds across
        the mesh."""
        ref = pic_fits["jnp"]
        est = DistributedBanditPAM(K, mesh, metric="l2", seed=SEED,
                                   backend="jnp", reuse="pic",
                                   cache_width=128)   # one round-batch
        capped = est.fit(data)
        assert sorted(capped.medoids.tolist()) == sorted(
            ref.medoids.tolist())
        assert capped.loss == pytest.approx(ref.loss, rel=1e-6)
        assert capped.distance_evals >= ref.distance_evals

    def test_sharded_pic_facade_roundtrip(data, mesh):
        est = KMedoids(K, solver="banditpam_dist", metric="l2", seed=SEED,
                       backend="jnp", mesh=mesh, reuse="pic",
                       cache_width=512).fit(np.asarray(data))
        assert est.report_.cached_evals > 0
        assert est.labels_.shape == (N,)

    def test_uneven_tiny_n_with_empty_shards(mesh):
        # n < n_loc * n_shards leaves whole shards as padding; their
        # stratum weight is 0 and the fit must still match exact PAM.
        tiny = datasets.mnist_like(10, seed=2)
        r = DistributedBanditPAM(2, mesh, metric="l2", seed=SEED).fit(tiny)
        ref = pam(tiny, 2, metric="l2")
        assert r.loss == pytest.approx(ref.loss, rel=1e-4)

    def test_n_smaller_than_mesh(mesh):
        # n below the device count: the cyclic padding wraps the data
        # more than once (regression: a single clamped pad slice left the
        # sharded view short of a shard multiple and device_put raised).
        micro = datasets.mnist_like(3, seed=4)
        r = DistributedBanditPAM(2, mesh, metric="l2", seed=SEED).fit(micro)
        ref = pam(micro, 2, metric="l2")
        assert r.loss == pytest.approx(ref.loss, rel=1e-4)

    # -- facade round-trip ----------------------------------------------
    def test_facade_roundtrip_on_mesh(data, mesh):
        est = KMedoids(K, solver="banditpam_dist", metric="l2", seed=SEED,
                       backend="jnp", mesh=mesh).fit(np.asarray(data))
        assert est.report_.solver == "banditpam_dist"
        assert est.labels_.shape == (N,)
        assert np.array_equal(est.predict(np.asarray(data)), est.labels_)
        assert est.report_.distance_evals > 0
        assert set(est.report_.wall_by_phase) == {"build", "swap"}

    # -- curator gating ---------------------------------------------------
    def test_curator_gates_on_mesh_device_count(monkeypatch):
        """The distributed path keys on the MESH's device count, not the
        host's: a 1-device mesh on a multi-device host must run the
        single-device solver; a multi-device sub-mesh must go sharded."""
        emb = datasets.mnist_like(40, seed=5)

        class Boom:
            def __init__(self, *a, **kw):
                raise AssertionError("distributed path taken")

        monkeypatch.setattr(dist, "DistributedBanditPAM", Boom)
        m1 = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        meds, assign = MedoidCurator(2, m1, metric="l2").curate(emb)
        assert meds.shape == (2,) and assign.shape == (40,)
        m4 = Mesh(np.asarray(jax.devices()[:4]), ("data",))
        with pytest.raises(AssertionError, match="distributed path taken"):
            MedoidCurator(2, m4, metric="l2").curate(emb)

    # -- RNG round-collision regression ----------------------------------
    def test_draws_fold_round_step_and_phase():
        """Regression for the round-collision bug: the historical key
        chain ignored the round counter (and the BUILD selection index),
        so rounds could silently replay identical reference batches."""
        b_loc, v = 16, 13
        pk = dist._phase_key(SEED, dist._BUILD_TAG, 0)
        d00 = np.asarray(dist._shard_draws(dist._round_key(pk, 0), 0, v, b_loc))
        d01 = np.asarray(dist._shard_draws(dist._round_key(pk, 1), 0, v, b_loc))
        assert not np.array_equal(d00, d01)          # round folded in
        pk1 = dist._phase_key(SEED, dist._BUILD_TAG, 1)
        d10 = np.asarray(dist._shard_draws(dist._round_key(pk1, 0), 0, v, b_loc))
        assert not np.array_equal(d00, d10)          # selection folded in
        pks = dist._phase_key(SEED, dist._SWAP_TAG, 0)
        ds0 = np.asarray(dist._shard_draws(dist._round_key(pks, 0), 0, v, b_loc))
        assert not np.array_equal(d00, ds0)          # phase folded in
        again = np.asarray(dist._shard_draws(dist._round_key(pk, 0), 0, v, b_loc))
        np.testing.assert_array_equal(d00, again)    # ... deterministically

    def test_no_two_rounds_of_a_fit_see_identical_batches(fits, mesh):
        """Reconstruct every stratified draw the seed-SEED fit consumed
        (the chain is a pure function of (seed, phase, step, round,
        shard)) — over a superset of the executed rounds — and assert no
        two rounds produced the same global reference batch."""
        r = fits["jnp"]
        est = DistributedBanditPAM(K, mesh, metric="l2", seed=SEED)
        n_shards = est.n_shards
        n_loc = -(-N // n_shards)
        b_loc = est.batch_size // n_shards
        rmax = -(-N // est.batch_size) + 1           # replacement-mode cap
        seen = set()
        for tag, steps in ((dist._BUILD_TAG, K),
                           (dist._SWAP_TAG, r.n_swaps + 1)):
            for step in range(steps):
                pk = dist._phase_key(SEED, tag, step)
                for rnd in range(rmax):
                    rk = dist._round_key(pk, rnd)
                    batch = tuple(
                        int(i) for ax in range(n_shards) for i in np.asarray(
                            dist._shard_draws(
                                rk, ax, min(max(N - ax * n_loc, 0), n_loc),
                                b_loc)))
                    assert batch not in seen, (tag, step, rnd)
                    seen.add(batch)

    def test_sharded_stats_vary_with_round_counter(data, mesh):
        """The production smap itself (not just the key helpers) must
        return different statistics for different round counters — under
        the old keying, stats_fn was constant in ``rnd``."""
        est = DistributedBanditPAM(K, mesh, metric="l2", seed=SEED,
                                   backend="jnp")
        be = get_stats_backend("jnp")
        x = jnp.asarray(data, jnp.float32)
        data_sh = est._shard_data(x)
        smap = est._build_smap(be, N)
        dnear = jnp.full((N,), jnp.inf, jnp.float32)
        pk = dist._phase_key(SEED, dist._BUILD_TAG, 0)
        lead = jnp.int32(0)
        s0, _, _ = smap(x, data_sh, dnear, dist._round_key(pk, 0), lead)
        s1, _, _ = smap(x, data_sh, dnear, dist._round_key(pk, 1), lead)
        s0b, _, _ = smap(x, data_sh, dnear, dist._round_key(pk, 0), lead)
        assert not np.allclose(np.asarray(s0), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s0b))
