"""Shared fixtures: the tracecheck runtime-guard harness.

The fixtures live in ``repro.analysis.guard`` (so shipping code and
benchmarks can reuse the harness); re-exporting them here makes pytest
discover them for every test module.
"""

from repro.analysis.guard import fit_guard, trace_guard  # noqa: F401
