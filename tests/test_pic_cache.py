"""Bounded-width PIC cache (repro.core.pic_cache): the cache_width knob,
round recycling (exact fallback, unchanged medoids/loss), ledger
bit-parity at sufficient width, and the O(n·width) footprint — plus the
baselines bugfix regressions that ride the same PR (Voronoi
empty-cluster collapse, CLARANS non-medoid sampling)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BanditPAM, datasets, clarans, voronoi_iteration
from repro.core.baselines import _voronoi_update
from repro.core.pic_cache import (DEFAULT_CACHE_ROUNDS, make_cache,
                                  resolve_batch_cache_rounds,
                                  resolve_cache_rounds)


def _ledger(rep):
    return (rep.medoids.tolist(), rep.distance_evals, rep.cached_evals,
            dict(rep.evals_by_phase), rep.n_swaps)


# ---------------------------------------------------------------------------
# cache_width knob resolution
# ---------------------------------------------------------------------------

def test_resolve_cache_rounds():
    # default: bounded by DEFAULT_CACHE_ROUNDS, never past the round budget
    assert resolve_cache_rounds(5, 100, None) == 5
    assert resolve_cache_rounds(1000, 100, None) == DEFAULT_CACHE_ROUNDS
    # explicit widths round DOWN to whole round-blocks, clamped to budget
    assert resolve_cache_rounds(20, 100, 250) == 2
    assert resolve_cache_rounds(20, 100, 100) == 1
    assert resolve_cache_rounds(3, 100, 10_000) == 3
    with pytest.raises(ValueError):
        resolve_cache_rounds(20, 100, 50)   # narrower than one round-batch


def test_default_footprint_is_o_n_width_not_o_n_squared():
    """Acceptance: no [n, n·B] allocation at n = 1e5 — the default width
    is a fixed number of round-batches, orders of magnitude below n."""
    n, B = 100_000, 100
    rounds = resolve_cache_rounds(-(-n // B), B, None)
    width = rounds * B
    assert width == DEFAULT_CACHE_ROUNDS * B
    assert width * 20 < n                       # width << n
    # and the full historical width would have been n columns
    assert width < (-(-n // B)) * B


def test_make_cache_shape_and_state():
    c = make_cache(64, 16, 4)
    assert c.cols.shape == (64, 64)
    assert int(c.hw) == 0 and int(c.fresh_pos) == 0


# ---------------------------------------------------------------------------
# Recycling semantics on real fits
# ---------------------------------------------------------------------------

def test_sufficient_width_reproduces_unbounded_ledger_bit_identically():
    """A cap wide enough to hold every round ever materialised must be
    indistinguishable from the historical unbounded buffer — medoids,
    loss, and the itemised fresh/cached ledger all bit-identical."""
    data = datasets.mnist_like(500, seed=13)
    full = BanditPAM(5, metric="l2", seed=0, reuse="pic",
                     cache_width=500).fit(data)      # full round budget
    dflt = BanditPAM(5, metric="l2", seed=0, reuse="pic").fit(data)
    assert _ledger(full) == _ledger(dflt)
    assert full.loss == dflt.loss


@pytest.mark.parametrize("cache_width", [100, 200])
def test_tiny_cap_recycles_exactly(cache_width):
    """A deliberately tiny ring forces recycling: medoids and loss are
    unchanged (recycled rounds are recomputed bit-identically), the
    fresh count rises, cached reads fall — and some reads still hit."""
    data = datasets.mnist_like(500, seed=13)
    ref = BanditPAM(5, metric="l2", seed=0, reuse="pic").fit(data)
    capped = BanditPAM(5, metric="l2", seed=0, reuse="pic",
                       cache_width=cache_width).fit(data)
    assert sorted(capped.medoids.tolist()) == sorted(ref.medoids.tolist())
    assert capped.loss == pytest.approx(ref.loss, rel=1e-6)
    assert capped.n_swaps == ref.n_swaps
    assert capped.distance_evals > ref.distance_evals
    assert capped.cached_evals < ref.cached_evals
    assert capped.cached_evals > 0


def test_tiny_cap_fused_matches_stepped():
    """The recycling window logic is identical in the fused and stepped
    drivers (including the carry-drop once hw > W)."""
    data = datasets.mnist_like(400, seed=3)
    a = BanditPAM(4, metric="l2", seed=1, reuse="pic", cache_width=100,
                  fused=True).fit(data)
    b = BanditPAM(4, metric="l2", seed=1, reuse="pic", cache_width=100,
                  fused=False).fit(data)
    assert _ledger(a) == _ledger(b)
    assert a.loss == pytest.approx(b.loss, rel=1e-6)


def test_tiny_cap_backend_parity():
    data = datasets.mnist_like(300, seed=7)
    a = BanditPAM(3, metric="l2", seed=0, reuse="pic", cache_width=100,
                  backend="jnp").fit(data)
    b = BanditPAM(3, metric="l2", seed=0, reuse="pic", cache_width=100,
                  backend="pallas").fit(data)
    assert _ledger(a) == _ledger(b)


def test_cache_width_narrower_than_batch_raises():
    data = datasets.mnist_like(200, seed=0)
    with pytest.raises(ValueError):
        BanditPAM(3, metric="l2", reuse="pic", cache_width=50).fit(data)


def test_warm_block_clamped_to_ring_capacity():
    """cache_cols larger than the ring just warms the whole ring."""
    data = datasets.mnist_like(400, seed=5)
    r = BanditPAM(3, metric="l2", seed=0, reuse="pic", cache_width=200,
                  cache_cols=400).fit(data)
    assert r.evals_by_phase["cache_warm"] == 400 * 200
    ref = BanditPAM(3, metric="l2", seed=0, reuse="pic",
                    cache_width=200).fit(data)
    assert sorted(r.medoids.tolist()) == sorted(ref.medoids.tolist())


# ---------------------------------------------------------------------------
# Bugfix regression: Voronoi empty-cluster collapse
# ---------------------------------------------------------------------------

def test_voronoi_update_keeps_medoid_of_empty_cluster():
    """Duplicated medoid points leave one cluster empty (argmin sends
    every point to the lower index); the update must keep the previous
    medoid instead of electing argmin-of-all-inf == point 0."""
    rng = np.random.default_rng(0)
    data = rng.normal(size=(20, 4)).astype(np.float32)
    data[7] = data[3]                        # exact duplicate pair
    medoids = jnp.asarray(np.asarray([3, 7], np.int32))
    new_medoids, assign = _voronoi_update(jnp.asarray(data), medoids,
                                          metric="l2", k=2)
    new = np.asarray(new_medoids)
    assert not np.any(np.asarray(assign) == 1)     # cluster 1 is empty
    assert new[1] == 7                             # kept, not point 0
    assert len(set(new.tolist())) == 2             # no duplicate medoids


def test_voronoi_iteration_never_duplicates_medoids_on_duplicate_data():
    rng = np.random.default_rng(3)
    base = rng.normal(size=(12, 3)).astype(np.float32)
    data = np.concatenate([base, base], axis=0)    # every point duplicated
    for seed in range(6):
        r = voronoi_iteration(data, k=4, metric="l2", seed=seed)
        assert len(set(r.medoids.tolist())) == 4


# ---------------------------------------------------------------------------
# Bugfix regression: CLARANS bounded non-medoid sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(5, 3), (4, 3), (6, 5)])
def test_clarans_terminates_with_tiny_non_medoid_pool(n, k):
    """n - k <= 2 historically re-drew (x in medoids -> continue) with
    probability ~k/n per attempt and no bound; sampling directly from
    the non-medoid pool terminates in exactly max_neighbors rejected
    draws."""
    rng = np.random.default_rng(1)
    data = rng.normal(size=(n, 3)).astype(np.float32)
    r = clarans(data, k=k, metric="l2", seed=0, num_local=2,
                max_neighbors=25)
    assert len(set(r.medoids.tolist())) == k
    # every candidate draw was a valid non-medoid: the eval ledger is
    # exactly (initial loss + accepted/rejected candidate losses) * n*k
    assert r.distance_evals % (n * k) == 0


def test_clarans_quality_unchanged():
    data = datasets.mnist_like(200, seed=11)
    r = clarans(data, k=3, metric="l2", seed=0, max_neighbors=80)
    v = voronoi_iteration(data, k=3, metric="l2", seed=0)
    assert r.loss <= v.loss * 1.25          # same quality tier as before


def test_resolve_batch_cache_rounds_is_max_of_solo_widths():
    """The batched ring width must cover every lane's solo ring: a fit
    that would not recycle alone must not recycle in the batch (the
    bit-parity guarantee of fit_batch under reuse="pic")."""
    ns, B = [47, 260, 33], 100
    solo = [resolve_cache_rounds(-(-n // B), B, None) for n in ns]
    assert resolve_batch_cache_rounds(ns, B) == max(solo)
    # explicit width caps propagate through the same clamping
    assert resolve_batch_cache_rounds(ns, B, cache_width=200) == max(
        resolve_cache_rounds(-(-n // B), B, 200) for n in ns)
    # degenerate single-lane batch == the solo resolution
    assert resolve_batch_cache_rounds([512], B) == resolve_cache_rounds(
        -(-512 // B), B, None)
    with pytest.raises(ValueError, match="narrower"):
        resolve_batch_cache_rounds(ns, B, cache_width=10)
