"""Distribution substrate: int8 EF compression math, sharding rules, and a
subprocess multi-device check (shard_map compressed psum vs exact psum;
distributed BanditPAM equivalence lives in test_distributed_banditpam)."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import dequantize_int8, quantize_int8

# The mesh axis_types / top-level shard_map API needs jax >= 0.6; the pure
# compression-math tests below run everywhere.
requires_modern_jax = pytest.mark.skipif(
    not (hasattr(jax, "shard_map") and hasattr(jax.sharding, "AxisType")),
    reason="needs jax.shard_map / jax.sharding.AxisType (jax >= 0.6)")


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """Over repeated steps the EF residual keeps the *accumulated* quantized
    sum close to the accumulated true sum (bias does not grow)."""
    rng = np.random.default_rng(1)
    residual = jnp.zeros((32,), jnp.float32)
    acc_true = np.zeros(32)
    acc_q = np.zeros(32)
    for t in range(50):
        g = jnp.asarray(rng.standard_normal(32).astype(np.float32)) * 0.01
        xr = g + residual
        q, s = quantize_int8(xr)
        deq = dequantize_int8(q, s)
        residual = xr - deq
        acc_true += np.asarray(g)
        acc_q += np.asarray(deq)
    # EF guarantees |acc_true - acc_q| = |last residual| <= one quantum
    assert np.max(np.abs(acc_true - acc_q)) <= float(s) + 1e-6


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import psum_int8_ef

    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = jnp.arange(2 * 4 * 16, dtype=jnp.float32).reshape(8, 16) * 0.01
    res = jnp.zeros((8, 16), jnp.float32)

    def f(xl, rl):
        s, r = psum_int8_ef(xl[0], rl[0], "pod")
        exact = jax.lax.psum(xl[0], "pod")
        return s[None], exact[None], r[None]

    g = jax.shard_map(f, mesh=mesh, in_specs=(P(("pod", "data")), P(("pod", "data"))),
                      out_specs=(P(("pod", "data")), P(("pod", "data")),
                                 P(("pod", "data"))))
    s, exact, r = g(x.reshape(8, 16), res)
    err = float(jnp.max(jnp.abs(s - exact)))
    scale = float(jnp.max(jnp.abs(exact)))
    print(json.dumps({"err": err, "scale": scale}))
""")


@requires_modern_jax
def test_compressed_psum_matches_exact_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                         text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                          "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # int8 quantization: relative error ~< 1/127 per term
    assert res["err"] <= res["scale"] / 64 + 1e-5, res


def test_sharding_rules_noop_without_mesh():
    from repro.distributed.sharding import shard, spec_for
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "d_model") is x
    assert spec_for("batch") == jax.sharding.PartitionSpec()


@requires_modern_jax
def test_spec_for_with_mesh_rules():
    from repro.distributed import sharding as sh
    # fake mesh context: use the 1-device mesh but full rule table
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh.set_mesh(mesh)
    try:
        assert sh.spec_for("batch", None, "ff") == \
            jax.sharding.PartitionSpec(None, None, "model")
    finally:
        sh.clear()
