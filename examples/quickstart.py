"""Quickstart: any registered solver vs the exact PAM reference, driven
through the unified ``repro.api.KMedoids`` facade.

    PYTHONPATH=src python examples/quickstart.py [--n 2000] [--k 5]
        [--solver banditpam] [--metric l2]

``--solver``/``--metric`` choices come straight from the registries, so
solvers and metrics registered by user code show up automatically.
``--metric precomputed`` exercises the matrix path: the script computes
the [n, n] L2 dissimilarity matrix up front and both solvers consume it
without recomputing a single distance.
"""
import argparse
import time

import numpy as np

from repro.api import (KMedoids, available_metrics, available_solvers,
                       default_params)
from repro.core import datasets, pairwise


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--solver", default="banditpam",
                    choices=available_solvers())
    ap.add_argument("--metric", default="l2", choices=available_metrics())
    args = ap.parse_args()

    # one draw, split into a fit set and an in-distribution held-out set
    full = datasets.mnist_like(args.n + 256, seed=0)
    data, queries = full[:args.n], full[args.n:]
    if args.metric == "precomputed":
        X = np.asarray(pairwise(data, data, metric="l2"))
        Q = np.asarray(pairwise(queries, data, metric="l2"))
    else:
        X, Q = data, queries
    print(f"data: {data.shape}, metric={args.metric}, "
          f"solver={args.solver}, k={args.k}")

    t0 = time.time()
    ref = KMedoids(args.k, solver="fastpam1", metric=args.metric).fit(X)
    t_ref = time.time() - t0
    print(f"pam (exact)  medoids={sorted(ref.medoids_.tolist())} "
          f"loss={ref.loss_:.2f} "
          f"dist_evals={ref.report_.distance_evals:,} ({t_ref:.1f}s)")

    params = default_params(args.solver)
    t0 = time.time()
    est = KMedoids(args.k, solver=args.solver, metric=args.metric, seed=0,
                   **params).fit(X)
    t_est = time.time() - t0
    print(f"{args.solver:12s} medoids={sorted(est.medoids_.tolist())} "
          f"loss={est.loss_:.2f} "
          f"dist_evals={est.report_.distance_evals:,} ({t_est:.1f}s)")
    print(f"same medoids as PAM: "
          f"{sorted(ref.medoids_.tolist()) == sorted(est.medoids_.tolist())}")
    print(f"distance-evaluation reduction: "
          f"{ref.report_.distance_evals / max(est.report_.distance_evals, 1):.1f}x")

    labels = est.predict(Q)
    print(f"out-of-sample predict on {len(labels)} new points: cluster sizes "
          f"{np.bincount(labels, minlength=args.k).tolist()}")


if __name__ == "__main__":
    main()
