"""Quickstart: BanditPAM vs exact PAM on a synthetic MNIST-like set.

    PYTHONPATH=src python examples/quickstart.py [--n 2000] [--k 5]
"""
import argparse
import time

from repro.core import BanditPAM, datasets, pam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--metric", default="l2",
                    choices=["l2", "l2sq", "l1", "cosine"])
    args = ap.parse_args()

    data = datasets.mnist_like(args.n, seed=0)
    print(f"data: {data.shape}, metric={args.metric}, k={args.k}")

    t0 = time.time()
    p = pam(data, args.k, metric=args.metric)
    t_pam = time.time() - t0
    print(f"PAM        medoids={sorted(p.medoids.tolist())} "
          f"loss={p.loss:.2f} dist_evals={p.distance_evals:,} ({t_pam:.1f}s)")

    t0 = time.time()
    b = BanditPAM(args.k, metric=args.metric, seed=0, baseline="leader").fit(data)
    t_bp = time.time() - t0
    print(f"BanditPAM  medoids={sorted(b.medoids.tolist())} "
          f"loss={b.loss:.2f} dist_evals={b.distance_evals:,} ({t_bp:.1f}s)")
    print(f"same medoids as PAM: {sorted(p.medoids) == sorted(b.medoids)}")
    print(f"distance-evaluation reduction: "
          f"{p.distance_evals / max(b.distance_evals, 1):.1f}x")


if __name__ == "__main__":
    main()
