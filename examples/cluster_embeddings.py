"""Cluster LM hidden states with k-medoids (the paper's technique as a
first-class feature of the LM stack).

Runs a reduced qwen3 backbone over synthetic documents, takes the final
hidden state of each document as its embedding, and finds k interpretable
*exemplar documents* (medoids) under cosine distance — the pattern used
for data curation / routing at scale.  Any registered solver/metric works
through the ``repro.api.KMedoids`` facade (``repro.core.distributed.
MedoidCurator`` is the mesh-aware variant of the same operation).

    PYTHONPATH=src python examples/cluster_embeddings.py [--solver ...]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (KMedoids, available_metrics, available_solvers,
                       default_params)
from repro.configs import get_reduced
from repro.models import model as M
from repro.train import synthetic_batch


def embed_documents(cfg, params, n_docs: int, seq: int = 32):
    embs = []
    for step in range(n_docs // 16):
        batch = synthetic_batch(cfg, 16, seq, step)
        # mean-pooled final hidden state as the document embedding
        logits, _ = M.forward(cfg, params, {"tokens": batch["tokens"]})
        # reuse the pre-head activations via a tiny probe: embed from logits
        # is fine for the demo; production hooks forward() with return_h.
        embs.append(np.asarray(jnp.mean(logits, axis=1)))
    return np.concatenate(embs, 0).astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--solver", default="banditpam",
                    choices=available_solvers())
    # choices derived from the metric registry, so user-registered metrics
    # are selectable too ("precomputed" needs a matrix, not embeddings)
    ap.add_argument("--metric", default="cosine",
                    choices=[m for m in available_metrics()
                             if m != "precomputed"])
    args = ap.parse_args()

    cfg = get_reduced("qwen3_1_7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"embedding {args.docs} synthetic documents with reduced "
          f"{cfg.name} ...")
    embs = embed_documents(cfg, params, args.docs)
    print(f"embeddings: {embs.shape}; clustering k={args.k} "
          f"({args.solver}, {args.metric})")

    est = KMedoids(args.k, solver=args.solver, metric=args.metric, seed=0,
                   **default_params(args.solver)).fit(embs)
    sizes = np.bincount(est.labels_, minlength=args.k)
    print(f"exemplar documents (medoid ids): {sorted(est.medoids_.tolist())}")
    print(f"cluster sizes: {sizes.tolist()}")
    print("every cluster center IS one of the input documents — that is "
          "the k-medoids interpretability win the paper targets.")


if __name__ == "__main__":
    main()
