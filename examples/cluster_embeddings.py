"""Cluster LM hidden states with BanditPAM (the paper's technique as a
first-class feature of the LM stack).

Runs a reduced qwen3 backbone over synthetic documents, takes the final
hidden state of each document as its embedding, and finds k interpretable
*exemplar documents* (medoids) under cosine distance — the pattern used
for data curation / routing at scale (MedoidCurator is mesh-aware).

    PYTHONPATH=src python examples/cluster_embeddings.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.distributed import MedoidCurator
from repro.models import model as M
from repro.train import synthetic_batch


def embed_documents(cfg, params, n_docs: int, seq: int = 32):
    embs = []
    for step in range(n_docs // 16):
        batch = synthetic_batch(cfg, 16, seq, step)
        # mean-pooled final hidden state as the document embedding
        logits, _ = M.forward(cfg, params, {"tokens": batch["tokens"]})
        # reuse the pre-head activations via a tiny probe: embed from logits
        # is fine for the demo; production hooks forward() with return_h.
        embs.append(np.asarray(jnp.mean(logits, axis=1)))
    return np.concatenate(embs, 0).astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--k", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced("qwen3_1_7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"embedding {args.docs} synthetic documents with reduced "
          f"{cfg.name} ...")
    embs = embed_documents(cfg, params, args.docs)
    print(f"embeddings: {embs.shape}; clustering k={args.k} (cosine)")

    medoids, assign = MedoidCurator(args.k, metric="cosine").curate(embs)
    sizes = np.bincount(assign, minlength=args.k)
    print(f"exemplar documents (medoid ids): {sorted(medoids.tolist())}")
    print(f"cluster sizes: {sizes.tolist()}")
    print("every cluster center IS one of the input documents — that is "
          "the k-medoids interpretability win the paper targets.")


if __name__ == "__main__":
    main()
