"""End-to-end training driver: LM training with medoid-curated data and a
fault-tolerant loop (checkpoint every N steps, auto-resume).

Curation: every R steps the pipeline embeds a candidate pool, clusters it
with BanditPAM, and re-weights sampling toward cluster medoids (coreset
selection) — the paper's algorithm in the data path.

Presets: --preset cpu-small (~5M params, runs in minutes on this
container) | --preset 100m (the ~100M target config; same code path, run
it on real accelerators).

    PYTHONPATH=src python examples/train_lm_curated.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BanditPAM, medoid_cache
from repro.models import model as M
from repro.runtime.fault import FaultTolerantLoop
from repro.train import (OptConfig, init_opt_state, make_train_step,
                         synthetic_batch)

PRESETS = {
    "cpu-small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                      head_dim=32, d_ff=384, vocab=2048),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=32000),
}


def curate_weights(cfg, params, step, pool=64, k=8, seq=32):
    """Cluster a candidate pool of sequences; upweight medoid-near docs."""
    batch = synthetic_batch(cfg, pool, seq, 10_000 + step)
    logits, _ = M.forward(cfg, params, {"tokens": batch["tokens"]})
    emb = jnp.mean(logits, axis=1).astype(jnp.float32)
    fit = BanditPAM(k, metric="cosine", seed=step, baseline="leader").fit(emb)
    _, _, assign = medoid_cache(emb, jnp.asarray(fit.medoids), metric="cosine")
    # balanced-coverage weights: inverse cluster frequency
    sizes = np.bincount(np.asarray(assign), minlength=k).astype(np.float32)
    w = 1.0 / sizes[np.asarray(assign)]
    return batch, w / w.sum()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--curate-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("qwen3_1_7b"), **PRESETS[args.preset])
    n_params = cfg.param_count()["total"]
    print(f"arch=qwen3-family preset={args.preset} params~{n_params/1e6:.1f}M")

    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    ocfg = OptConfig(lr=3e-3, warmup_steps=20)
    opt = init_opt_state(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg, microbatches=1))

    loop = FaultTolerantLoop(args.ckpt_dir, save_every=50)
    state = {"params": params, "opt": opt}
    state, start = loop.restore_or(state)
    if start:
        print(f"resumed from checkpoint at step {start}")

    curation = {"w": None}
    t0 = time.time()
    losses = []

    def one_step(st, i):
        if i % args.curate_every == 0:
            _, w = curate_weights(cfg, st["params"], i)
            curation["w"] = w
            print(f"  [curate] step {i}: medoid-balanced pool "
                  f"(max_w/min_w={w.max()/w.min():.1f})")
        batch = synthetic_batch(cfg, args.batch, args.seq, i)
        p, o, m = step_fn(st["params"], st["opt"], batch)
        losses.append(float(m["loss"]))
        if i % 20 == 0:
            print(f"  step {i:4d} loss {losses[-1]:.3f} "
                  f"gnorm {float(m['grad_norm']):.2f}")
        return {"params": p, "opt": o}, m

    state = loop.run(state, one_step, n_steps=args.steps, start_step=start)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
