"""Batched serving example: prefill a batch of prompts, then greedy-decode
with the rolling KV caches (same step functions the dry-run lowers for the
decode_32k / long_500k cells).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3_1_7b --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import model as M
from repro.serve.lm import greedy_decode, make_prefill_step
from repro.train import synthetic_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    cache_len = args.prompt_len + args.tokens

    batch = synthetic_batch(cfg, args.batch, args.prompt_len, 0)
    prompts = {"tokens": batch["tokens"]}
    if "patch_emb" in batch:
        prompts["patch_emb"] = batch["patch_emb"]

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    t0 = time.time()
    first_logits, state = prefill(params, prompts)
    first_tok = jnp.argmax(first_logits, axis=-1).astype(jnp.int32)
    if cfg.frontend == "audio_stub":
        first_tok = first_tok.reshape(args.batch, 1, cfg.n_codebooks)
    else:
        first_tok = first_tok.reshape(args.batch, 1)
    t_prefill = time.time() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({t_prefill:.2f}s incl. compile)")

    t0 = time.time()
    out, _ = greedy_decode(cfg, params, state, first_tok,
                           start_pos=args.prompt_len, n_tokens=args.tokens)
    t_dec = time.time() - t0
    tps = args.batch * args.tokens / t_dec
    print(f"decode: {args.tokens} tokens x {args.batch} seqs "
          f"({t_dec:.2f}s incl. compile, {tps:.0f} tok/s)")
    print("sample continuation (seq 0):", out[0].reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
