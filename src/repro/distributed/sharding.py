"""Logical-axis sharding rules -> mesh PartitionSpecs.

Models annotate activations/params with *logical* axis names; the mapping
to physical mesh axes lives here so the same model code runs on 1 CPU
device (rules unset -> no-op), a single pod (16x16 data/model) or the
multi-pod mesh (2x16x16 pod/data/model).

Physical conventions (docs/design.md §5):
  batch   -> ("pod", "data")   data parallelism, hierarchical across pods
  heads   -> "model"           Megatron-style tensor parallelism (q heads)
  kv_heads-> replicated        GQA: kv head count (8) < model extent (16)
  ff / d_inner / experts / vocab -> "model"
  seq     -> None by default; "data" for long-context decode (SP), where
             the KV/SSM state, not the batch, is the big axis.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "d_model": None,
    "heads": ("model",),
    "kv_heads": None,
    "head_dim": None,
    "ff": ("model",),
    "d_inner": ("model",),
    "ssm_state": None,
    "experts": ("model",),
    "vocab": ("model",),
    "expert_cap": None,
    "codebooks": None,
    # Decode caches shard their sequence axis over "model" (SP-for-decode):
    # the masked cache write is shard-local and the softmax reductions over
    # the sharded axis communicate only O(B*H) scalars per layer.  The
    # long-context cell widens this to every mesh axis (launch/dryrun.py).
    "kv_seq": ("model",),
}


def set_mesh(mesh: Optional[Mesh], rules: Optional[Dict] = None) -> None:
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def clear() -> None:
    set_mesh(None)


def spec_for(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for a tensor whose dims carry these logical names."""
    mesh = get_mesh()
    if mesh is None:
        return P()
    rules = getattr(_state, "rules", DEFAULT_RULES)
    axis_names = set(mesh.axis_names)
    parts = []
    for ax in logical_axes:
        phys = rules.get(ax) if ax is not None else None
        if phys is None:
            parts.append(None)
        else:
            got = tuple(p for p in phys if p in axis_names)
            parts.append(got if len(got) > 1 else (got[0] if got else None))
    return P(*parts)


def sharding_for(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(*logical_axes))


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    s = sharding_for(*logical_axes)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
