"""int8 error-feedback gradient compression for the cross-pod all-reduce.

Inside a pod, ICI links are fast (~50 GB/s/link); across pods the data-
center network is the bottleneck, so only the **pod-axis** leg of the
gradient reduction is compressed:

    g_pod  = full-precision reduction inside the pod (XLA autodiff)
    q, s   = int8 quantize(g_pod + residual)       (per-tensor scale)
    G      = sum_p dequant(all_gather(q, s))       (4x fewer bytes than
                                                    an f32 ring all-reduce)
    residual' = (g_pod + residual) - dequant(q, s)  (error feedback)

Error feedback makes the compression *unbiased over time*: quantization
error is carried into the next step instead of being dropped, which keeps
SGD convergence (Karimireddy et al., 2019).  Validated against the exact
psum in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(F32))) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(F32) * scale


def psum_int8_ef(x: jnp.ndarray, residual: jnp.ndarray, axis_name: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compressed psum over `axis_name` with error feedback.

    Must run inside shard_map with `axis_name` manual.  Returns
    (global_sum ~ psum(x), new_residual).
    """
    xr = x.astype(F32) + residual
    q, scale = quantize_int8(xr)
    deq_local = dequantize_int8(q, scale)
    new_residual = xr - deq_local
    qg = jax.lax.all_gather(q, axis_name)            # [P, ...] int8 on wire
    sg = jax.lax.all_gather(scale, axis_name)        # [P] scalars
    total = jnp.tensordot(sg, qg.astype(F32), axes=([0], [0]))
    return total, new_residual


def tree_psum_int8_ef(tree: Any, residuals: Any, axis_name: str
                      ) -> Tuple[Any, Any]:
    flat, tdef = jax.tree.flatten(tree)
    rflat = tdef.flatten_up_to(residuals)
    outs = [psum_int8_ef(g, r, axis_name) for g, r in zip(flat, rflat)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_residuals(tree: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), tree)
