from . import compression, sharding
from .sharding import set_mesh, shard, sharding_for, spec_for

__all__ = ["compression", "sharding", "set_mesh", "shard", "sharding_for",
           "spec_for"]
