"""GPipe-style pipeline parallelism over a mesh axis (usually "pod").

A composable schedule, not a model rewrite: hand it a per-stage function
and per-stage parameters (layers split across the axis), it runs the
``M + S - 1``-tick bubble schedule with ``ppermute`` hops between stages,
inside ``shard_map``.  Autodiff through the schedule yields the standard
GPipe backward (activations stashed per tick by the scan), so
``jax.grad`` works out of the box.

Trade-off notes (docs/design.md §6): for the assigned models on a pod-pair,
pod-as-data + int8-EF-compressed gradient all-reduce moves fewer cross-pod
bytes than PP activations for train_4k (activations/tick: B·L·d·2 bytes x
(M+S-1) ticks vs one compressed grad all-reduce); PP wins when the model
does not fit a single pod's HBM — which is why it ships as a first-class
option rather than the default.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
          n_stages: int, axis: str = "pod"):
    """Build the in-shard_map pipeline runner.

    stage_fn: (stage_params, x [mb, ...]) -> y [mb, ...] — one stage's
      compute (e.g. a scan over that stage's layer slice).
    Returns runner(stage_params_local, mbs [M, mb, ...]) -> [M, mb, ...]
      producing the LAST stage's outputs (valid on every rank for ease of
      loss computation; other ranks compute them redundantly-masked).
    """

    def runner(stage_params, mbs):
        s = n_stages
        sid = jax.lax.axis_index(axis)
        m = mbs.shape[0]
        t_total = m + s - 1
        zero = jnp.zeros_like(mbs[0])
        perm = [(i, (i + 1) % s) for i in range(s)]

        def tick(buf, t):
            # stage 0 injects microbatch t (while in range), others take buf
            inject = mbs[jnp.clip(t, 0, m - 1)]
            x = jnp.where(sid == 0, inject, buf)
            y = stage_fn(stage_params, x)
            buf_next = jax.lax.ppermute(y, axis, perm)
            return buf_next, y

        _, ys = jax.lax.scan(tick, zero, jnp.arange(t_total))
        # outputs of the last stage appear at ticks [s-1, s-1+m); broadcast
        # them to every stage so callers can compute the loss uniformly.
        out = jax.lax.dynamic_slice_in_dim(ys, s - 1, m, axis=0)
        # ys holds THIS stage's outputs; select the last stage's via psum
        # of the masked value (exactly one stage contributes).
        mask = (sid == s - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    return runner


def pipeline_map(stage_fn, mesh: Mesh, n_stages: int, axis: str = "pod",
                 params_spec=P("pod"), x_spec=P(None)):
    """shard_map wrapper: params split over the stage axis, microbatches
    replicated in, last-stage outputs replicated out."""
    runner = gpipe(stage_fn, n_stages, axis)
    return jax.shard_map(runner, mesh=mesh, in_specs=(params_spec, x_spec),
                         out_specs=x_spec, check_vma=False)
