"""CLARA-style weighted reservoir over the ingest stream.

The serving layer cannot keep every point it has ever seen, but a refit
needs a sample that (a) fits in one solver call and (b) over-represents
the points the current medoids serve BADLY — exactly the points a drift
refit must fix.  Kaufman & Rousseeuw's CLARA grounds the shape (PAM-class
solve on a bounded subsample); the sampling rule is A-Res weighted
reservoir sampling (Efraimidis & Spirakis 2006): each stream point i with
weight ``w_i > 0`` draws ``u_i ~ U(0,1)`` and gets the key
``r_i = u_i^(1/w_i)``; the reservoir keeps the ``capacity`` largest keys.
The kept set is then a weighted sample without replacement of *everything
ever offered*, regardless of stream order or chunking.

Two determinism properties the service's snapshot/resume contract leans
on:

* ``u_i`` is derived by folding the GLOBAL stream index ``i`` into a
  fixed PRNG key (threefry ``fold_in``, same construction as the batched
  engine's per-lane chains) — NOT by advancing a stateful generator.
  Splitting one 1000-point ingest into ten 100-point calls produces
  bit-identical reservoirs, and a restored service replays the exact
  keys the original would have drawn.
* The merge is a host-side f64 lexsort on ``(key desc, stream index
  asc)`` — a total order, so ties cannot make two replicas diverge.

State is a flat dict of numpy arrays (see :meth:`state`) that rides
``runtime/checkpoint.py`` untouched.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Reservoir"]


@jax.jit
def _stream_uniforms(key, idx):
    """``u_i ~ U(0,1)`` for global stream indices ``idx`` — one threefry
    fold per index, so the draw depends only on (key, i)."""
    return jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(idx)


@functools.lru_cache(maxsize=None)
def _uniform_bucket(m: int) -> int:
    """Pad index batches to power-of-two buckets: bounded jit variants of
    ``_stream_uniforms`` over a ragged ingest stream."""
    return 1 << (max(1, m) - 1).bit_length()


class Reservoir:
    """Bounded weighted sample of the ingest stream (A-Res keys).

    Args:
      capacity: maximum points held.
      d: feature dimension.
      seed: base PRNG key for the per-index uniforms.
    """

    def __init__(self, capacity: int, d: int, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.d = int(d)
        self.seed = int(seed)
        self._key = jax.random.PRNGKey(self.seed)
        self.pts = np.zeros((self.capacity, self.d), np.float32)
        self.keys = np.full((self.capacity,), -np.inf, np.float64)
        self.sidx = np.full((self.capacity,), -1, np.int64)
        self.filled = 0
        self.seen = 0       # total stream points ever offered

    # -- ingest ----------------------------------------------------------
    def offer(self, points: np.ndarray, weights: Optional[np.ndarray] = None
              ) -> None:
        """Offer ``[m, d]`` points with optional positive weights.

        Stream indices are assigned internally (``seen .. seen+m``), so
        callers only ever append — the chunking of a stream into offer()
        calls is not observable in the final reservoir.
        """
        pts = np.asarray(points, np.float32)
        if pts.ndim != 2 or pts.shape[1] != self.d:
            raise ValueError(f"expected [m, {self.d}] points, "
                             f"got {pts.shape}")
        m = pts.shape[0]
        if m == 0:
            return
        if weights is None:
            w = np.ones((m,), np.float64)
        else:
            w = np.asarray(weights, np.float64).ravel()
            if w.shape[0] != m:
                raise ValueError("weights/points length mismatch")
            if (w <= 0).any():
                raise ValueError("weights must be positive")
        idx = self.seen + np.arange(m, dtype=np.int64)
        rows = _uniform_bucket(m)
        idx_pad = np.zeros((rows,), np.int64)
        idx_pad[:m] = idx
        u = np.asarray(_stream_uniforms(self._key, jnp.asarray(idx_pad)),
                       np.float64)[:m]
        # A-Res key in f64 on host; clamp u away from 0 so log is finite.
        r = np.exp(np.log(np.maximum(u, 1e-300)) / w)

        cat_pts = np.concatenate([self.pts[:self.filled], pts])
        cat_keys = np.concatenate([self.keys[:self.filled], r])
        cat_sidx = np.concatenate([self.sidx[:self.filled], idx])
        # Total order: key desc, then stream index asc — ties cannot
        # reorder between replicas.
        order = np.lexsort((cat_sidx, -cat_keys))[:self.capacity]
        keep = len(order)
        self.pts[:keep] = cat_pts[order]
        self.keys[:keep] = cat_keys[order]
        self.sidx[:keep] = cat_sidx[order]
        self.keys[keep:] = -np.inf
        self.sidx[keep:] = -1
        self.filled = keep
        self.seen += m

    # -- views -----------------------------------------------------------
    @property
    def points(self) -> np.ndarray:
        """``[filled, d]`` view of the held points."""
        return self.pts[:self.filled]

    def __len__(self) -> int:
        return self.filled

    # -- checkpoint state ------------------------------------------------
    def state(self) -> dict:
        """Flat numpy pytree for ``runtime.checkpoint`` (bit-exact:
        f64 keys and i64 counters round-trip as numpy leaves)."""
        return {"pts": self.pts.copy(), "keys": self.keys.copy(),
                "sidx": self.sidx.copy(),
                "filled": np.int64(self.filled),
                "seen": np.int64(self.seen)}

    def load_state(self, state: dict) -> None:
        pts = np.asarray(state["pts"], np.float32)
        if pts.shape != (self.capacity, self.d):
            raise ValueError(f"reservoir shape mismatch: snapshot "
                             f"{pts.shape} vs configured "
                             f"{(self.capacity, self.d)}")
        self.pts = pts.copy()
        self.keys = np.asarray(state["keys"], np.float64).copy()
        self.sidx = np.asarray(state["sidx"], np.int64).copy()
        self.filled = int(state["filled"])
        self.seen = int(state["seen"])
