"""LM serving step factories (quarantined scaffolding).

Prefill (prompt -> caches + first logits) and single-token decode against
the sharded caches.  Batched request serving drives these from
examples/serve_lm.py; the dry-run lowers them for the decode_32k /
long_500k cells.

This module is the dormant language-model side of ``repro.serve`` and is
deliberately kept OUT of the package front: ``repro.serve`` fronts the
streaming k-medoids :class:`~repro.serve.service.MedoidService`; LM
consumers import ``repro.serve.lm`` explicitly (formerly
``repro.serve.serve_step``)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M


def make_prefill_step(cfg: ArchConfig, cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        logits, _, state = M.forward(cfg, params, batch, collect_state=True,
                                     cache_len=cache_len)
        return logits[:, -1:], state
    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, state, batch, pos):
        logits, state = M.decode_step(cfg, params, state, batch, pos)
        return logits, state
    return decode_step


def greedy_decode(cfg: ArchConfig, params, state, first_token, start_pos: int,
                  n_tokens: int):
    """Host-side greedy loop used by the serving example."""
    step = jax.jit(make_decode_step(cfg))
    tok = first_token
    out = []
    for i in range(n_tokens):
        logits, state = step(params, state, {"tokens": tok},
                             jnp.int32(start_pos + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        if cfg.frontend == "audio_stub":
            tok = tok  # [B,1,nc] argmax already per codebook
        out.append(tok)
    return jnp.concatenate(out, axis=1), state
