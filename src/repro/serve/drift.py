"""Assignment-loss drift monitor for the serving layer.

The fitted medoids imply a baseline: the mean nearest-medoid distance
``mu0`` over the data they were fitted on (``FitReport.loss / n``).  As
the stream distribution moves, the mean assignment loss of INGESTED
points rises above that baseline; once enough evidence accumulates the
monitor trips and the service refits.

Drift rule (documented in docs/design.md and tested for determinism):

    trip  iff  count >= window  and  sum/count > (1 + threshold) * mu0

``window`` guards against tripping on a handful of outliers right after a
refit; ``threshold`` is the relative loss excursion the service
tolerates.  All accounting is exact host-side f64 over the f32 per-point
distances the predict closure already produced — no extra dispatches,
and bit-identical between a live service and one restored mid-stream
(the counters ride the checkpoint as f64/i64 numpy leaves).
"""

from __future__ import annotations

import numpy as np

__all__ = ["DriftMonitor"]


class DriftMonitor:
    """Windowed mean-loss drift detector.

    Args:
      threshold: relative excursion over baseline that trips a refit
        (0.25 = mean ingest loss 25% above the fitted mean).
      window: minimum ingested points before the monitor may trip.
    """

    def __init__(self, threshold: float = 0.25, window: int = 256):
        if threshold < 0:
            raise ValueError(f"threshold must be >= 0, got {threshold}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.threshold = float(threshold)
        self.window = int(window)
        self.baseline = np.float64(np.inf)   # mu0; inf = never trips
        self.sum = np.float64(0.0)
        self.count = np.int64(0)

    def reset(self, baseline: float) -> None:
        """Re-arm after a (re)fit with the new mean per-point loss."""
        self.baseline = np.float64(baseline)
        self.sum = np.float64(0.0)
        self.count = np.int64(0)

    def update(self, dmin: np.ndarray) -> None:
        """Fold a chunk of nearest-medoid distances into the window."""
        d = np.asarray(dmin, np.float64).ravel()
        self.sum = np.float64(self.sum + d.sum())
        self.count = np.int64(self.count + d.shape[0])

    @property
    def mean(self) -> float:
        return float(self.sum / self.count) if self.count else 0.0

    @property
    def drifted(self) -> bool:
        if self.count < self.window or not np.isfinite(self.baseline):
            return False
        return bool(self.sum / self.count
                    > (1.0 + self.threshold) * self.baseline)

    # -- checkpoint state ------------------------------------------------
    def state(self) -> dict:
        """f64/i64 numpy leaves — exact round-trip through
        ``runtime.checkpoint``."""
        return {"baseline": np.float64(self.baseline),
                "sum": np.float64(self.sum),
                "count": np.int64(self.count)}

    def load_state(self, state: dict) -> None:
        self.baseline = np.float64(state["baseline"])
        self.sum = np.float64(state["sum"])
        self.count = np.int64(state["count"])
