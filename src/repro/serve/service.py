"""MedoidService — the streaming k-medoids serving layer.

The paper's pitch is k-medoids cheap enough to run CONTINUOUSLY on live
data; this module is the layer that actually runs continuously.  One
service instance owns:

* **device-resident medoids** — a ``[k, d]`` block that every request is
  scored against through the cached jitted closures of
  ``repro.api.predict`` (``get_predict_fn``): request batching + fixed
  row buckets means a stream of ragged requests touches a bounded set of
  compiled programs and the hot path never retraces;
* **a CLARA-style weighted reservoir** (:class:`~repro.serve.reservoir.
  Reservoir`) — ingested points survive with probability proportional to
  their weight (default: their assignment loss, so badly-served points
  are over-represented in the next refit sample);
* **a drift monitor** (:class:`~repro.serve.drift.DriftMonitor`) — mean
  ingest loss vs. the fitted baseline; past ``(1 + threshold)·mu0`` over
  at least ``window`` points, the service refits itself;
* **refit machinery** — ``refit="warm"`` warm-starts BanditPAM SWAP from
  the current medoids over the PIC cache ring (``BanditPAM.fit(...,
  warm_start=...)``: BUILD is skipped entirely, so the warm ledger is
  strictly cheaper in fresh evaluations than a cold fit of the same
  sample); ``refit="onebatch"`` is the OneBatchPAM latency floor
  (``init=`` seeded from the serving medoids); ``refit="cold"`` is the
  full from-scratch control.

Everything that makes the service's future behaviour — medoids,
reservoir contents + A-Res keys, stream position (= RNG chain position:
every random draw is keyed on the global stream index), drift counters,
the cumulative fresh/cached ledger — snapshots through
``runtime/checkpoint.py`` and resumes BIT-identically: a restored
service fed the same remaining stream trips the same refits on the same
points and lands on the same medoids (tests/test_serve.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api.predict import (DEFAULT_CHUNK, assign_medoids,
                               medoid_distances, resolve_backend)
from repro.api.registry import (default_params, get_solver,
                                solver_accepts_backend)
from repro.core.banditpam import BanditPAM
from repro.core.distances import resolve_metric
from repro.core.onebatch import onebatchpam
from repro.core.report import FitReport
from repro.runtime import checkpoint as ckpt

from .drift import DriftMonitor
from .reservoir import Reservoir

__all__ = ["MedoidService", "IngestResult"]

REFIT_MODES = ("warm", "onebatch", "cold")
RESERVOIR_WEIGHTS = ("loss", "uniform")

# Mixes the refit ordinal into the per-refit solver seed so successive
# refits explore distinct SWAP chains while staying a pure function of
# (service seed, refit count) — the snapshot/resume contract.
_REFIT_SEED_STRIDE = 1_000_003


@dataclass
class IngestResult:
    """What one ``ingest`` call did: assignments for the offered points
    and, if the drift monitor tripped, the refit's report."""
    labels: np.ndarray                     # [m] int32
    dmin: np.ndarray                       # [m] float32 nearest-medoid dist
    refit: Optional[FitReport] = None      # set when this call refitted
    drift_mean: float = 0.0                # monitor mean AFTER this chunk


@dataclass
class _Ledger:
    """Cumulative fresh/cached evaluation ledger across fit + refits."""
    fresh: int = 0
    cached: int = 0
    refits: List[Dict] = field(default_factory=list)

    def add(self, report: FitReport, kind: str, wall_s: float) -> None:
        led = report.ledger()
        self.fresh += int(led["fresh"])
        self.cached += int(led["cached"])
        self.refits.append({
            "kind": kind, "loss": float(report.loss),
            "fresh": int(led["fresh"]), "cached": int(led["cached"]),
            "n_swaps": int(report.n_swaps),
            "converged": bool(report.converged),
            "wall_s": float(wall_s)})


class MedoidService:
    """Online k-medoids: serve, ingest, auto-refit on drift.

    Args:
      k: number of medoids.
      metric: REGISTERED metric name (callables and ``"precomputed"`` are
        rejected — serving needs feature vectors it can re-score).
      solver: facade solver for the initial ``fit`` (registry name).
      solver_params: params for the initial fit (default:
        ``registry.default_params(solver)``).
      refit: ``"warm"`` | ``"onebatch"`` | ``"cold"`` — refit strategy.
      refit_params: extra params for the refit solver (e.g.
        ``{"cache_width": 16}`` for warm, ``{"ref_size": 512}`` for
        onebatch).
      reservoir_size: points held for refits (CLARA sample bound).
      reservoir_weights: ``"loss"`` (assignment-loss weighted — the
        badly-served survive) or ``"uniform"``.
      drift_threshold / drift_window: see :class:`DriftMonitor`.
      backend: stats-backend for fit/refit/predict (``"auto"`` resolves
        per the engine's one TPU rule).
      request_chunk: predict/ingest chunk bound (row-bucket ceiling).
      seed: service seed — owns the reservoir key chain and refit seeds.
    """

    def __init__(self, k: int, metric: str = "l2", *,
                 solver: str = "banditpam_pp",
                 solver_params: Optional[dict] = None,
                 refit: str = "warm",
                 refit_params: Optional[dict] = None,
                 reservoir_size: int = 2048,
                 reservoir_weights: str = "loss",
                 drift_threshold: float = 0.25,
                 drift_window: int = 256,
                 backend: str = "auto",
                 request_chunk: int = DEFAULT_CHUNK,
                 seed: int = 0):
        if int(k) < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        metric = resolve_metric(metric)
        if metric == "precomputed":
            raise ValueError("MedoidService requires feature vectors; "
                             "metric='precomputed' cannot score new points")
        if refit not in REFIT_MODES:
            raise ValueError(f"refit must be one of {REFIT_MODES}, "
                             f"got {refit!r}")
        if reservoir_weights not in RESERVOIR_WEIGHTS:
            raise ValueError(f"reservoir_weights must be one of "
                             f"{RESERVOIR_WEIGHTS}, got {reservoir_weights!r}")
        self.k = int(k)
        self.metric = metric
        self.solver = solver
        self.solver_params = (dict(solver_params) if solver_params is not None
                              else default_params(solver))
        self.refit_mode = refit
        self.refit_params = dict(refit_params or {})
        self.reservoir_size = int(reservoir_size)
        self.reservoir_weights = reservoir_weights
        self.drift_threshold = float(drift_threshold)
        self.drift_window = int(drift_window)
        self.backend = backend
        self.request_chunk = int(request_chunk)
        self.seed = int(seed)
        # fitted state
        self.medoid_points: Optional[jnp.ndarray] = None    # [k, d] device
        self.d: Optional[int] = None
        self.reservoir: Optional[Reservoir] = None
        self.drift = DriftMonitor(self.drift_threshold, self.drift_window)
        self.n_refits = 0
        self.ledger = _Ledger()
        self.last_report: Optional[FitReport] = None

    # -- fit -------------------------------------------------------------
    def fit(self, X) -> "MedoidService":
        """Initial offline fit; seeds the reservoir with the training
        points and arms the drift monitor at the fitted mean loss."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected [n, d] data, got {X.shape}")
        n = X.shape[0]
        if n <= self.k:
            raise ValueError(f"need n > k, got n={n}, k={self.k}")
        self.d = int(X.shape[1])
        params = dict(self.solver_params)
        if solver_accepts_backend(self.solver):
            params.setdefault("backend", self.backend)
        t0 = time.perf_counter()
        report = get_solver(self.solver)(jnp.asarray(X), self.k,
                                         metric=self.metric, seed=self.seed,
                                         **params)
        wall = time.perf_counter() - t0
        self.medoid_points = jnp.asarray(X[np.asarray(report.medoids)])
        self.last_report = report
        self.ledger.add(report, "fit", wall)
        self.reservoir = Reservoir(self.reservoir_size, self.d,
                                   seed=self.seed)
        # The training points flow through the same ingest weighting as
        # the stream (their dmin also warms the predict closure).
        _, dmin = self._assign(X)
        self.reservoir.offer(X, self._weights(dmin))
        self.drift.reset(report.loss / n)
        return self

    # -- serve -----------------------------------------------------------
    def _require_fitted(self):
        if self.medoid_points is None:
            raise RuntimeError("MedoidService is not fitted; call fit() "
                               "or restore()")

    def _assign(self, X) -> Tuple[np.ndarray, np.ndarray]:
        # request_chunk only bounds transform(): the assignment path is
        # chunk-free streaming (its chunk= kwarg is deprecated).
        return assign_medoids(X, self.medoid_points, self.metric,
                              backend=self.backend)

    def predict(self, X) -> np.ndarray:
        """``[m, d]`` queries → ``[m]`` medoid labels (one cached-closure
        dispatch per row bucket; no retrace on the hot path)."""
        self._require_fitted()
        return self._assign(np.asarray(X, np.float32))[0]

    def transform(self, X) -> np.ndarray:
        """``[m, d]`` queries → ``[m, k]`` distances to the medoids."""
        self._require_fitted()
        return medoid_distances(np.asarray(X, np.float32),
                                self.medoid_points, self.metric,
                                backend=self.backend,
                                chunk=self.request_chunk)

    # -- ingest + drift --------------------------------------------------
    def _weights(self, dmin: np.ndarray) -> np.ndarray:
        if self.reservoir_weights == "uniform":
            return np.ones_like(dmin, np.float64)
        # loss weighting: eps floor keeps zero-distance duplicates alive
        # with small (not zero) survival probability.
        d = np.asarray(dmin, np.float64)
        return d + 1e-6 * max(1.0, float(d.mean()) if d.size else 1.0)

    def ingest(self, X) -> IngestResult:
        """Score a stream chunk, fold it into the reservoir + drift
        window, and refit if the monitor trips."""
        self._require_fitted()
        X = np.asarray(X, np.float32)
        labels, dmin = self._assign(X)
        self.reservoir.offer(X, self._weights(dmin))
        self.drift.update(dmin)
        refit_report = None
        if self.drift.drifted:
            refit_report = self._refit()
        return IngestResult(labels=labels, dmin=dmin, refit=refit_report,
                            drift_mean=self.drift.mean)

    # -- refit -----------------------------------------------------------
    def _refit_data(self) -> Tuple[np.ndarray, np.ndarray]:
        """Refit sample: current medoids (rows 0..k) + reservoir points.
        Keeping the medoids in the candidate set makes warm-start indices
        trivially valid and lets a converged SWAP keep them."""
        med = np.asarray(self.medoid_points, np.float32)
        data = np.concatenate([med, self.reservoir.points], axis=0)
        return data, np.arange(self.k, dtype=np.int64)

    def _refit_seed(self) -> int:
        return self.seed + _REFIT_SEED_STRIDE * (self.n_refits + 1)

    def refit_report_pair(self) -> Tuple[FitReport, FitReport]:
        """Run the configured warm refit AND a cold control on the SAME
        sample (no state mutation) — the ledger comparison surfaced in
        benchmarks/serve_bench.py and the end-to-end test."""
        self._require_fitted()
        data, warm_idx = self._refit_data()
        seed = self._refit_seed()
        return (self._run_refit(data, warm_idx, seed),
                self._run_refit(data, None, seed))

    def _run_refit(self, data: np.ndarray, warm_idx: Optional[np.ndarray],
                   seed: int) -> FitReport:
        if self.refit_mode == "onebatch":
            return onebatchpam(data, self.k, metric=self.metric, seed=seed,
                               backend=self.backend,
                               init=warm_idx, **self.refit_params)
        params = dict(self.refit_params)
        params.setdefault("reuse", "pic")
        if params["reuse"] == "pic" and "cache_width" not in params:
            # Serving refits default to a HALF-COVERAGE ring: wide enough
            # that the carried-moment repair path serves real cached
            # reads, narrow enough that the ring keeps recycling — a
            # fully resident ring mostly subsidises the cold BUILD the
            # warm path exists to skip.  Refit samples are ephemeral, so
            # there is no cross-fit residency to protect.
            B = int(params.get("batch_size", 100))
            n_rounds = -(-data.shape[0] // B)
            params["cache_width"] = max(1, n_rounds // 2) * B
        est = BanditPAM(self.k, metric=self.metric, seed=seed,
                        backend=self.backend, **params)
        if self.refit_mode == "cold" and warm_idx is not None:
            warm_idx = None
        return est.fit(jnp.asarray(data), warm_start=warm_idx)

    def _refit(self) -> FitReport:
        data, warm_idx = self._refit_data()
        seed = self._refit_seed()
        t0 = time.perf_counter()
        report = self._run_refit(
            data, None if self.refit_mode == "cold" else warm_idx, seed)
        wall = time.perf_counter() - t0
        self.n_refits += 1
        self.medoid_points = jnp.asarray(
            data[np.asarray(report.medoids)], jnp.float32)
        self.last_report = report
        self.ledger.add(report, f"refit:{self.refit_mode}", wall)
        self.drift.reset(report.loss / data.shape[0])
        return report

    # -- introspection ---------------------------------------------------
    def stats(self) -> Dict:
        """Host-side service counters (JSON-safe)."""
        return {"seen": int(self.reservoir.seen) if self.reservoir else 0,
                "reservoir_filled": len(self.reservoir)
                if self.reservoir else 0,
                "n_refits": int(self.n_refits),
                "fresh_evals": int(self.ledger.fresh),
                "cached_evals": int(self.ledger.cached),
                "drift_mean": self.drift.mean,
                "drift_count": int(self.drift.count),
                "baseline": float(self.drift.baseline)}

    # -- snapshot / resume ----------------------------------------------
    def _state_tree(self) -> Dict:
        """The full behavioural state as a checkpoint pytree.  Device
        leaf: ``medoid_points``.  Everything else is host numpy (f64/i64)
        and round-trips bit-exactly (see runtime.checkpoint.restore)."""
        return {"medoid_points": self.medoid_points,
                "reservoir": self.reservoir.state(),
                "drift": self.drift.state(),
                "counters": {"n_refits": np.int64(self.n_refits),
                             "fresh": np.int64(self.ledger.fresh),
                             "cached": np.int64(self.ledger.cached)}}

    def config(self) -> Dict:
        return {"k": self.k, "metric": self.metric, "solver": self.solver,
                "solver_params": self.solver_params,
                "refit": self.refit_mode, "refit_params": self.refit_params,
                "reservoir_size": self.reservoir_size,
                "reservoir_weights": self.reservoir_weights,
                "drift_threshold": self.drift_threshold,
                "drift_window": self.drift_window,
                "backend": self.backend,
                "request_chunk": self.request_chunk,
                "seed": self.seed, "d": self.d}

    def snapshot(self, ckpt_dir: str, step: Optional[int] = None) -> str:
        """Write the service state under ``ckpt_dir`` (atomic publish).
        ``step`` defaults to the stream position so successive snapshots
        never collide."""
        self._require_fitted()
        if step is None:
            step = int(self.reservoir.seen)
        extra = {"service": self.config(),
                 "refits": self.ledger.refits}
        return ckpt.save(ckpt_dir, step, self._state_tree(), extra=extra)

    @classmethod
    def restore(cls, ckpt_dir: str, step: Optional[int] = None,
                shardings=None) -> "MedoidService":
        """Rebuild a service from a snapshot.  ``shardings`` (optional)
        is a pytree matching :meth:`_state_tree` — pass a NamedSharding
        for ``medoid_points`` to restore onto a different mesh; host
        leaves take ``None`` and come back as exact numpy."""
        extra = ckpt.read_extra(ckpt_dir, step=step)
        cfg = dict(extra["service"])
        d = cfg.pop("d")
        svc = cls(cfg.pop("k"), cfg.pop("metric"),
                  solver=cfg.pop("solver"),
                  solver_params=cfg.pop("solver_params"),
                  refit=cfg.pop("refit"),
                  refit_params=cfg.pop("refit_params"),
                  reservoir_size=cfg.pop("reservoir_size"),
                  reservoir_weights=cfg.pop("reservoir_weights"),
                  drift_threshold=cfg.pop("drift_threshold"),
                  drift_window=cfg.pop("drift_window"),
                  backend=cfg.pop("backend"),
                  request_chunk=cfg.pop("request_chunk"),
                  seed=cfg.pop("seed"))
        svc.d = int(d)
        svc.reservoir = Reservoir(svc.reservoir_size, svc.d, seed=svc.seed)
        template = {"medoid_points": jnp.zeros((svc.k, svc.d), jnp.float32),
                    "reservoir": svc.reservoir.state(),
                    "drift": svc.drift.state(),
                    "counters": {"n_refits": np.int64(0),
                                 "fresh": np.int64(0),
                                 "cached": np.int64(0)}}
        tree, _ = ckpt.restore(ckpt_dir, template, step=step,
                               shardings=shardings)
        svc.medoid_points = tree["medoid_points"]
        svc.reservoir.load_state(tree["reservoir"])
        svc.drift.load_state(tree["drift"])
        svc.n_refits = int(tree["counters"]["n_refits"])
        svc.ledger.fresh = int(tree["counters"]["fresh"])
        svc.ledger.cached = int(tree["counters"]["cached"])
        svc.ledger.refits = [dict(r) for r in extra.get("refits", [])]
        return svc
