"""``repro.serve`` — the streaming k-medoids serving layer.

Fronts :class:`MedoidService` (device-resident medoids, cached jitted
predict closures, CLARA-style weighted reservoir, drift-triggered
warm-start refits) plus its building blocks.  The dormant LM
prefill/decode scaffolding that used to live here is quarantined in
``repro.serve.lm`` — import it explicitly; it is intentionally NOT
re-exported from the package front.
"""

from .drift import DriftMonitor
from .reservoir import Reservoir
from .service import IngestResult, MedoidService

__all__ = ["DriftMonitor", "IngestResult", "MedoidService", "Reservoir"]
