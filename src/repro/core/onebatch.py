"""OneBatchPAM (de Mathelin et al. 2025) — the latency-floor k-medoids.

Where BanditPAM adaptively *grows* each arm's reference sample until the
confidence intervals separate, OneBatchPAM commits to ONE fixed reference
batch up front and solves the induced finite-sample k-medoids problem
exactly: the objective is the mean dissimilarity to the ``b`` batch
points instead of all ``n``, so the whole fit touches a single ``[n, b]``
distance block — one kernel residency, no bandit loop, no per-round
host/device round-trips.  The returned medoids approximate the full-data
optimum with the usual subsample guarantees (the same grounds as CLARA's
PAM-on-subsamples, but with *candidates* still ranging over all n points,
which is why it dominates CLARA at equal budget).

The fit itself is one jit (:func:`_onebatch_solve`): a ``fori_loop``
BUILD (greedy k selections against the batch objective) followed by a
``while_loop`` of best-improvement SWAP iterations in the FastPAM1
decomposition — per candidate x, one row of the resident block scores
all k removals via ``Δ(m, x) = Σ_j base_x(j) + Σ_{j∈C_m} corr_x(j)``.

Role in this repo: the *fast-path refit* of the streaming
``repro.serve.MedoidService`` — when assignment drift demands new
medoids NOW, one fixed-batch solve (optionally warm-started from the
serving medoids via ``init=``) is the cheapest answer that still
searches the full candidate set.  Registered on the facade as
``solver="onebatchpam"``.

Ledger: ``n·b`` fresh evaluations for the batch block plus ``n·k`` for
the final exact loss/assignment — everything else is replays of the
resident block, which the paper's accounting (and ours) counts once.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import get_stats_backend, resolve_stats_backend, total_loss
from .report import FitReport

__all__ = ["onebatchpam", "DEFAULT_REF_SIZE"]

# Default reference-batch size: comfortably past the B=100 bandit round
# batch (same estimation grounds) while keeping the [n, b] block one
# kernel residency at serving scale.
DEFAULT_REF_SIZE = 256


@functools.partial(jax.jit, static_argnames=("k", "max_swaps", "do_build"))
def _onebatch_solve(D, init_meds, *, k: int, max_swaps: int, do_build: bool):
    """BUILD + SWAP against the fixed-batch objective, ONE jit.

    ``D`` is the resident ``[n, b]`` candidate-to-batch block.  With
    ``do_build=False`` the BUILD loop is skipped and ``init_meds`` seeds
    SWAP directly (the warm-start entry the serving layer uses).

    Returns (medoids, iters, converged, old[T], new[T], loss_b[T],
    acc[T]) — the swap trajectory over the *batch* objective, which the
    host turns into ``FitReport.swap_history``.
    """
    n, b = D.shape
    T = max_swaps

    if do_build:
        # Greedy BUILD: each selection minimises the batch loss given the
        # already-chosen medoids (dnear = running min over batch columns).
        def build_body(i, c):
            meds, mask, dnear = c
            scores = jnp.sum(jnp.minimum(D, dnear[None, :]), axis=1)
            scores = jnp.where(mask, jnp.inf, scores)
            m = jnp.argmin(scores).astype(jnp.int32)
            return (meds.at[i].set(m), mask.at[m].set(True),
                    jnp.minimum(dnear, D[m]))

        meds, mask, _ = jax.lax.fori_loop(
            0, k, build_body, (jnp.zeros((k,), jnp.int32),
                               jnp.zeros((n,), jnp.bool_),
                               jnp.full((b,), jnp.inf, jnp.float32)))
    else:
        meds = init_meds
        mask = jnp.zeros((n,), jnp.bool_).at[meds].set(True)

    def cond(st):
        return jnp.logical_and(st[0] < T, jnp.logical_not(st[1]))

    def body(st):
        t, done, meds, mask, old_a, new_a, loss_a, acc_a = st
        Dm = D[meds]                                        # [k, b]
        a_b = jnp.argmin(Dm, axis=0).astype(jnp.int32)      # [b]
        d1 = jnp.min(Dm, axis=0)
        Dm2 = Dm.at[a_b, jnp.arange(b)].set(jnp.inf)
        d2 = jnp.min(Dm2, axis=0)
        loss_b = jnp.sum(d1)
        # FastPAM1 decomposition over the resident block: one [n, b] x
        # [b, k] matmul scores every (candidate, removed-medoid) pair.
        md = jnp.minimum(D, d1[None, :])
        base = md - d1[None, :]                             # [n, b]
        corr = jnp.minimum(D, d2[None, :]) - md
        onehot = jax.nn.one_hot(a_b, k, dtype=D.dtype)      # [b, k]
        delta = jnp.sum(base, axis=1)[:, None] + corr @ onehot   # [n, k]
        delta = jnp.where(mask[:, None], jnp.inf, delta)
        best = jnp.argmin(delta.reshape(-1))
        x, m = best // k, best % k
        dval = delta.reshape(-1)[best]
        # The repo's one swap-accept rule (relative f32 margin).
        accept = dval < -1e-7 * jnp.maximum(1.0, jnp.abs(loss_b))
        old = meds[m]
        meds2 = jnp.where(accept, meds.at[m].set(x.astype(jnp.int32)), meds)
        mask2 = jnp.where(accept,
                          mask.at[old].set(False).at[x].set(True), mask)
        return (t + 1, jnp.logical_not(accept), meds2, mask2,
                old_a.at[t].set(old), new_a.at[t].set(x.astype(jnp.int32)),
                loss_a.at[t].set(loss_b + dval), acc_a.at[t].set(accept))

    st0 = (jnp.int32(0), jnp.bool_(False), meds, mask,
           jnp.zeros((T,), jnp.int32), jnp.zeros((T,), jnp.int32),
           jnp.zeros((T,), jnp.float32), jnp.zeros((T,), jnp.bool_))
    t, done, meds, _, old_a, new_a, loss_a, acc_a = jax.lax.while_loop(
        cond, body, st0)
    return meds, t, done, old_a, new_a, loss_a, acc_a


def onebatchpam(data, k: int, *, metric: str = "l2",
                ref_size: Optional[int] = None, seed: int = 0,
                max_swaps: Optional[int] = None, init=None,
                backend: str = "auto") -> FitReport:
    """Fit k medoids against ONE fixed reference batch.

    Args:
      data: ``[n, d]`` float32 (index-augmented for ``"precomputed"``).
      ref_size: reference-batch size ``b`` (clamped to n; default
        ``min(n, DEFAULT_REF_SIZE)``).
      init: optional ``[k]`` medoid indices — skips BUILD and warm-starts
        SWAP from them (the serving layer's incremental-refit entry).
      backend: stats-backend name for the one pairwise block
        (``repro.core.engine``; ``"auto"`` resolves like every solver).

    Returns a :class:`FitReport` whose ``loss`` is the EXACT full-data
    loss of the selected medoids (one final ``n·k`` pass), while the
    search itself only ever paid the ``n·b`` batch block.
    """
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    if n <= k:
        raise ValueError("need n > k")
    b = min(n, int(ref_size) if ref_size is not None else DEFAULT_REF_SIZE)
    if b < 1:
        raise ValueError(f"ref_size must be >= 1, got {ref_size}")
    T = int(max_swaps) if max_swaps is not None else 4 * int(k) + 10
    bname = resolve_stats_backend(backend, metric)
    be = get_stats_backend(bname)

    key = jax.random.PRNGKey(seed)
    ref = jax.random.choice(key, n, shape=(b,), replace=False
                            ).astype(jnp.int32)
    D = be.pairwise(data, data[ref], metric=metric)         # [n, b]

    if init is not None:
        ws = np.asarray(init, np.int64).ravel()
        if ws.shape[0] != k or len(set(ws.tolist())) != k:
            raise ValueError(f"init must be {k} distinct medoid indices, "
                             f"got {ws.tolist()}")
        if ws.min() < 0 or ws.max() >= n:
            raise ValueError(f"init indices out of range [0, {n})")
        init_meds = jnp.asarray(ws, jnp.int32)
    else:
        init_meds = jnp.zeros((k,), jnp.int32)
    meds, iters, done, old_a, new_a, loss_a, acc_a = _onebatch_solve(
        D, init_meds, k=int(k), max_swaps=T, do_build=init is None)

    meds_np = np.asarray(meds, np.int64)
    loss = float(total_loss(data, meds, metric=metric))
    res = FitReport(medoids=meds_np, loss=loss, n_swaps=0,
                    converged=bool(done), distance_evals=0)
    res.evals_by_phase["ref_batch"] = n * b
    res.evals_by_phase["final_loss"] = n * k
    res.distance_evals = n * b + n * k
    old_np, new_np = np.asarray(old_a), np.asarray(new_a)
    la_np, acc_np = np.asarray(loss_a), np.asarray(acc_a)
    for t in range(int(iters)):
        if acc_np[t]:
            # the recorded loss is the BATCH objective after the swap
            res.swap_history.append((int(old_np[t]), int(new_np[t]),
                                     float(la_np[t])))
    res.n_swaps = len(res.swap_history)
    return res
