"""StatsBackend — the one seam between the bandit drivers and the
g-statistics compute paths.

Before this layer existed the Pallas kernels (``repro.kernels.ops``) were
exercised only by tests and benchmarks while the real fit path ran
pure-jnp statistics.  ``StatsBackend`` unifies the three g-statistics
paths behind one contract so the drivers are backend-agnostic and the
kernels power the actual fit:

* ``"jnp"``    — jit'd XLA math (``_build_g`` / ``_swap_batch_stats``);
  works for every registered metric, including user callables and
  ``"precomputed"``.
* ``"pallas"`` — the fused TPU kernels (``kernels.ops.build_g_stats`` /
  ``swap_g_stats`` for fresh rounds, ``swap_g_stats_cached`` for rounds
  served from the device-resident PIC column cache).  Kernel-implemented
  metrics only; interpret-mode on CPU.
* cache-served — both backends read warm rounds from a resident distance
  block via the ``*_from_d`` methods (the Pallas side uses the dedicated
  cached-stats kernel for SWAP; BUILD stats from a resident block are
  distance-free vector math and share the jnp formula).

Selection is by name (``backend="auto" | "pallas" | "jnp"`` on
``BanditPAM`` / ``repro.api.KMedoids``); the registry is open so an
out-of-tree backend (a GPU Triton port, say) is one ``register_stats_backend``
call.  ``"auto"`` picks Pallas for kernel-implemented metrics on a real
accelerator and jnp everywhere else — interpret-mode Pallas on CPU is
correct but slow, so it must be requested explicitly.

``FitContext`` carries every piece of per-fit state (RNG key, the fixed
reference permutation, the device-resident PIC cache buffer and its
high-water mark) that historically leaked onto the ``BanditPAM`` instance,
making ``fit`` re-entrant.

The shared g-statistics math (``_build_g``, ``_swap_terms``,
``_swap_batch_stats``), the medoid cache, and the exact loss live here so
``core.banditpam``, ``core.pam``, and ``core.distributed`` all draw from
one definition.  Backends are collective-free by contract: the sharded
driver (``core.distributed``) calls ``pairwise`` + ``*_stats_from_d`` on
shard-local blocks inside ``shard_map`` and composes the cross-shard
``psum`` itself, so every registered backend reaches the distributed path
unchanged.  See docs/design.md for the numbered hardware adaptations.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import get_metric, pairwise
from .pic_cache import (PicCache, cache_read_or_write, carry_valid,  # noqa: F401
                        fresh_positions, make_cache,  # noqa: F401
                        resolve_cache_rounds)  # noqa: F401
from .tuning import (REF_TILE, TileConfig, observe as observe_tiles,  # noqa: F401
                     resolve_tile_config)  # noqa: F401

_EXACT_CHUNK = 512  # reference-chunk size for exact fallback passes

# The streaming kernels' reference-tile width must share the exact-pass
# chunk boundaries — that alignment is what makes the tile-walk
# accumulation order reproduce the chunked-scan ledgers bit-for-bit
# (docs/design.md #8).
assert REF_TILE == _EXACT_CHUNK, (REF_TILE, _EXACT_CHUNK)


# ---------------------------------------------------------------------------
# Shared cache / loss helpers — streaming forms (design.md #8): the
# distance block is reduced per row-tile as it is produced, so no
# ``[n, k]`` / ``[n, chunk]`` matrix is ever resident.  Small inputs
# (n <= one tile) take the single-block branch, which is byte-identical
# to the historical materialised path.
# ---------------------------------------------------------------------------

def _stream_rows(data: jnp.ndarray, tile: int, fn, init, axis: int = -1):
    """Walk ``data`` ([n, d], n > tile) in row tiles, writing each
    ``fn(xt)`` strip (a pytree of arrays with a ``tile``-long ``axis``)
    into the matching ``init`` output buffer at the tile's row offset.

    No padded copy of the input is ever formed — each step slices one
    [tile, d] strip, so the whole walk's temp footprint is one strip plus
    one [tile, ·] result block.  The final tile is realigned to end at
    row n; rows in the overlap are recomputed and rewritten with the
    same bytes (every registered metric is row-independent, and the
    per-row reduction shape is tile-offset-invariant)."""
    n = data.shape[0]
    nt = -(-n // tile)

    def body(i, out):
        start = jnp.minimum(i * tile, n - tile)
        xt = jax.lax.dynamic_slice_in_dim(data, start, tile, 0)
        return jax.tree_util.tree_map(
            lambda o, r: jax.lax.dynamic_update_slice_in_dim(
                o, r, start, axis % o.ndim),
            out, fn(xt))

    return jax.lax.fori_loop(0, nt, body, init)


def _top2_block(dmat: jnp.ndarray):
    """Single-pass nearest/second-nearest reduction of one distance
    block: no ``.at[].set(inf)`` copy — the runner-up min masks the
    winner's column with ``where`` instead of duplicating the block."""
    assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    d1 = jnp.min(dmat, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, dmat.shape, 1)
    d2 = jnp.min(jnp.where(cols == assign[:, None], jnp.inf, dmat), axis=1)
    return d1, d2, assign


def _stream_top2_jnp(x, med_pts, *, metric: str, tile: int = _EXACT_CHUNK):
    """Streaming top-2 over row tiles: ``[n, d]`` x ``[k, d]`` ->
    (d1, d2, assign), [n] each, with only one [tile, k] block live."""
    n = x.shape[0]
    fn = get_metric(metric)
    if n <= tile:
        return _top2_block(fn(x, med_pts))
    init = (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.int32))
    return _stream_rows(x, tile, lambda xt: _top2_block(fn(xt, med_pts)),
                        init)


@functools.partial(jax.jit, static_argnames=("metric", "tile"))
def medoid_cache(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str,
                 tile: Optional[int] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d1 (nearest-medoid dist), d2 (second nearest), assignment; [n] each.
    One streaming top-2 pass — the hottest per-iteration helper holds a
    single [tile, k] block instead of ``[n, k]`` plus an inf-masked copy."""
    # tracecheck: ignore[TRC001] -- `tile` is in static_argnames: a host int
    # at trace time, never a traced value.
    t = _EXACT_CHUNK if tile is None else int(tile)
    return _stream_top2_jnp(data, data[medoids], metric=metric, tile=t)


@functools.partial(jax.jit, static_argnames=("metric", "tile"))
def total_loss(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str,
               w=None, tile: Optional[int] = None) -> jnp.ndarray:
    """Sum of nearest-medoid dissimilarities.  ``w`` (optional bool [n])
    masks rows out of the sum — the batched multi-fit path scores padded
    datasets with it (``jnp.where``, not a multiply, so NaN rows from
    degenerate pad points cannot poison the loss).  The nearest-distance
    vector is reduced tile-by-tile; the final sum runs over the intact
    [n] vector so summation order (and the ledger's loss bits) match the
    historical materialised path."""
    # tracecheck: ignore[TRC001] -- `tile` is in static_argnames: a host int
    # at trace time, never a traced value.
    t = _EXACT_CHUNK if tile is None else int(tile)
    n = data.shape[0]
    med = data[medoids]
    fn = get_metric(metric)
    if n <= t:
        dmin = jnp.min(fn(data, med), axis=1)
    else:
        dmin = _stream_rows(data, t,
                            lambda xt: jnp.min(fn(xt, med), axis=1),
                            jnp.zeros((n,), jnp.float32))
    if w is None:
        return jnp.sum(dmin)
    return jnp.sum(jnp.where(w, dmin, 0.0))


def _ref_chunks(n_ref: int, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static index/weight tiling of [0, n_ref) into equal chunks."""
    n_chunks = -(-n_ref // chunk)
    idx = np.arange(n_chunks * chunk)
    w = (idx < n_ref).astype(np.float32)
    idx = np.minimum(idx, n_ref - 1)
    return idx.reshape(n_chunks, chunk), w.reshape(n_chunks, chunk)


def exact_build_means(be, data, dnear, *, metric: str) -> jnp.ndarray:
    """Exact BUILD objective over the full reference set (Algorithm 1
    lines 13–15 fallback): per-arm mean g, [n].  Routed through the
    backend's streaming g-stats contract — one dispatch that walks
    ``_EXACT_CHUNK``-aligned reference tiles and accumulates online, so
    the resident block stays bounded and no ``[n, chunk]`` distance
    matrix is ever materialised — the one definition shared by the
    single-device and sharded drivers."""
    n = data.shape[0]
    return be.stream_build_sums(data, dnear, metric=metric) / n


def exact_swap_means(be, data, d1, d2, assign, k: int, *, metric: str
                     ) -> jnp.ndarray:
    """Exact SWAP objective over the flattened (medoid, candidate) arm
    set: per-arm mean g, [k·n]; same streaming backend-routed form as
    :func:`exact_build_means`."""
    n = data.shape[0]
    return be.stream_swap_sums(data, d1, d2, assign, k, metric=metric) / n


def _stream_build_sums_jnp(data, dnear, *, metric: str,
                           tile: int = _EXACT_CHUNK) -> jnp.ndarray:
    """jnp streaming BUILD g-sums, Σ_y g(x, y) over the whole dataset,
    [n].  The reference walk is the historical ``_ref_chunks`` scan (same
    tile boundaries, same per-tile op order, tiles added in walk order),
    row-tiled by ``lax.map`` so only a [tile, tile] block is live; inputs
    with n <= one tile take the single-row-block branch, which is the
    pre-streaming graph verbatim."""
    n = data.shape[0]
    idx_np, w_np = _ref_chunks(n, tile)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)
    fn = get_metric(metric)

    def walk(xt):
        def body(acc, iw):
            i, w_i = iw
            g = _build_g(fn(xt, data[i]), dnear[i]) * w_i[None, :]
            return acc + jnp.sum(g, axis=1), None
        out, _ = jax.lax.scan(body, jnp.zeros((xt.shape[0],), jnp.float32),
                              (idx, w))
        return out

    if n <= tile:
        return walk(data)
    return _stream_rows(data, tile, walk, jnp.zeros((n,), jnp.float32))


def _stream_swap_sums_jnp(data, d1, d2, assign, k: int, *, metric: str,
                          tile: int = _EXACT_CHUNK) -> jnp.ndarray:
    """jnp streaming SWAP g-sums over the flattened (medoid, candidate)
    arm set, [k·n]; same walk discipline as
    :func:`_stream_build_sums_jnp`."""
    n = data.shape[0]
    idx_np, w_np = _ref_chunks(n, tile)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)
    fn = get_metric(metric)

    def walk(xt):
        m = xt.shape[0]

        def body(acc, iw):
            i, w_i = iw
            s, _ = _swap_batch_stats(fn(xt, data[i]), d1[i], d2[i],
                                     assign[i], w_i, k)
            return acc + s, None
        out, _ = jax.lax.scan(body, jnp.zeros((k * m,), jnp.float32),
                              (idx, w))
        return out.reshape(k, m)

    if n <= tile:
        return walk(data).reshape(-1)
    return _stream_rows(data, tile, walk,
                        jnp.zeros((k, n), jnp.float32)).reshape(-1)


def stream_columns(be, data, refs, *, metric: str,
                   tile: int = _EXACT_CHUNK) -> jnp.ndarray:
    """Produce an ``[n, C]`` cache column block in row strips.

    The block itself IS the product here (warm/PIC caches store it), so
    its HBM footprint cannot be streamed away — but its *production* can
    be: each strip holds one [tile, C] distance block at a time instead
    of tracing a single [n, C] pairwise pass whose intermediates (e.g.
    the l2 cross-term matmul) scale with n.  Row strips are pinned to the
    ``_EXACT_CHUNK`` grid like every other walk (docs/design.md #8)."""
    n = data.shape[0]
    if n <= tile:
        return be.pairwise(data, refs, metric=metric)
    return _stream_rows(data, tile,
                        lambda xt: be.pairwise(xt, refs, metric=metric),
                        jnp.zeros((n, refs.shape[0]), jnp.float32), axis=0)


# ---------------------------------------------------------------------------
# g-statistics math (the Eq. 6 / Eq. 12 forms shared by every caller)
# ---------------------------------------------------------------------------

def _build_g(dxy: jnp.ndarray, dnear_b: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6 with the Eq. 4 special-case for the first assignment."""
    dn = dnear_b[None, :]
    return jnp.where(jnp.isinf(dn), dxy, jnp.minimum(dxy - dn, 0.0))


def _swap_terms(dxy: jnp.ndarray, d1_b: jnp.ndarray, d2_b: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    base = jnp.minimum(dxy, d1_b[None, :]) - d1_b[None, :]
    corr = jnp.minimum(dxy, d2_b[None, :]) - jnp.minimum(dxy, d1_b[None, :])
    return base, corr


def _swap_batch_stats(dxy, d1_b, d2_b, a_b, w, k, lead=None):
    """Per-arm (m·n + x) sums, square-sums (and optional leader cross-sums)
    over a reference batch.

    g = base + 1[assign==m]·corr  ⇒
      Σ g        = Σ base + Σ_{y∈C_m} corr
      Σ g²       = Σ base² + Σ_{y∈C_m} (2·base·corr + corr²)
      Σ g·g_lead = Σ base·g_lead + Σ_{y∈C_m} corr·g_lead
    The C_m-restricted sums are one-hot matmuls (MXU-shaped).
    """
    n = dxy.shape[0]
    base, corr = _swap_terms(dxy, d1_b, d2_b)
    # weights are {0,1} (padding mask), so w² = w and masking base once is
    # enough for every product below.
    base = base * w[None, :]
    onehot = jax.nn.one_hot(a_b, k, dtype=dxy.dtype) * w[:, None]   # [B, k]
    sums = jnp.sum(base, axis=1)[None, :] + (corr @ onehot).T       # [k, n]
    sq_base = jnp.sum(base * base, axis=1)
    sq_cross = 2.0 * base * corr + corr * corr
    sqsums = sq_base[None, :] + (sq_cross @ onehot).T
    if lead is None:
        return sums.reshape(-1), sqsums.reshape(-1)
    m_l, x_l = lead // n, lead % n
    g_lead = base[x_l] + onehot[:, m_l] * corr[x_l]                 # [B], w-masked
    cross = (base @ g_lead)[None, :] + ((corr * g_lead[None, :]) @ onehot).T
    return sums.reshape(-1), sqsums.reshape(-1), cross.reshape(-1)


# ---------------------------------------------------------------------------
# Device-resident PIC cache primitives: extracted to
# ``repro.core.pic_cache`` (bounded width + round recycling) and
# re-exported from the top of this module for the drivers and historical
# importers.
def counted_dispatch(fn, dispatches: Dict[str, int], phase: str):
    """Wrap a compiled phase callable so every driver-level dispatch is
    COUNTED at the call site — ``FitReport.dispatches_by_phase`` is a
    measurement, not a self-reported constant.  A refactor that
    re-introduces a per-selection host loop shows up in the recorded
    count (and trips ``benchmarks/distributed_bench.py``'s single-
    dispatch BUILD assertion) instead of being silently papered over."""
    def call(*args, **kw):
        dispatches[phase] = dispatches.get(phase, 0) + 1
        return fn(*args, **kw)
    return call


def host_read(x):
    """The sanctioned device→host read point for the drivers.

    Every ledger/convergence read in ``fit`` funnels through this one
    explicit ``jax.device_get`` so the whole fit runs clean under
    ``jax.transfer_guard("disallow")`` (which bans only *implicit*
    transfers): scattered ``float()``/``np.asarray()`` syncs would each
    be a separate, invisible transfer — and TRC001 findings if they
    leaked into jit-reachable code.  Accepts any pytree; returns numpy
    leaves (Python scalars pass through unchanged).
    """
    return jax.device_get(x)


@contextlib.contextmanager
def host_stage(reason: str):
    """Sanctioned host→device staging span (input upload, RNG chain
    head, context construction).  The ``reason`` is mandatory, mirroring
    the tracecheck suppression policy: every allowed transfer window
    names why it exists.  Inside the span the transfer guard is relaxed
    to "allow"; everything outside stays at the caller's level."""
    if not reason:
        raise ValueError("host_stage requires a non-empty reason")
    with jax.transfer_guard("allow"):
        yield


# ---------------------------------------------------------------------------
# StatsBackend implementations
# ---------------------------------------------------------------------------

class JnpStatsBackend:
    """Pure-XLA statistics: any registered metric, any device."""

    name = "jnp"

    def pairwise(self, x, y, *, metric):
        # The jit'd entrypoint: inlined when already inside a trace, and
        # compiled (not op-by-op eager) for eager callers like the
        # chunked predict path.
        return pairwise(x, y, metric=metric)

    # -- BUILD ----------------------------------------------------------
    def build_stats(self, data, ref_idx, dnear_b, w, lead, *, metric):
        """Fused fresh-round BUILD stats: (Σg, Σg², Σg·g_lead), [n] each."""
        return self.build_stats_from_d(
            get_metric(metric)(data, data[ref_idx]), dnear_b, w, lead)

    def build_stats_from_d(self, dxy, dnear_b, w, lead):
        """BUILD stats from a resident distance block (cache-served).
        ``lead=None`` skips the leader cross-sum (baseline="none")."""
        g = _build_g(dxy, dnear_b) * w[None, :]                     # [n, B]
        cross = (jnp.zeros((g.shape[0],), g.dtype) if lead is None
                 else g @ g[lead])
        return jnp.sum(g, axis=1), jnp.sum(g * g, axis=1), cross

    # -- SWAP (FastPAM1 fused form) -------------------------------------
    def swap_stats(self, data, ref_idx, d1_b, d2_b, assign_b, w, k, lead,
                   *, metric):
        """Fused fresh-round SWAP stats, flattened over the (m, x) arm set."""
        return self.swap_stats_from_d(get_metric(metric)(data, data[ref_idx]),
                                      d1_b, d2_b, assign_b, w, k, lead)

    def swap_stats_from_d(self, dxy, d1_b, d2_b, assign_b, w, k, lead):
        """SWAP stats from a resident distance block (cache-served)."""
        if lead is None:
            s, q = _swap_batch_stats(dxy, d1_b, d2_b, assign_b, w, k)
            return s, q, jnp.zeros_like(s)
        return _swap_batch_stats(dxy, d1_b, d2_b, assign_b, w, k, lead=lead)

    # -- streaming contract (exact fallback / top-2 serving passes) ------
    def stream_build_sums(self, data, dnear, *, metric):
        """Σ_y g(x, y) over the WHOLE dataset, [n] — no [n, chunk] block."""
        return _stream_build_sums_jnp(data, dnear, metric=metric)

    def stream_swap_sums(self, data, d1, d2, assign, k, *, metric):
        """Flattened (medoid, candidate) arm g-sums over the whole
        dataset, [k·n] — no [n, chunk] block."""
        return _stream_swap_sums_jnp(data, d1, d2, assign, k, metric=metric)

    def top2(self, x, med_pts, *, metric):
        """(d1, d2, assign) of x against medoid rows, [n] each, without
        materialising the [n, k] distance matrix."""
        return _stream_top2_jnp(x, med_pts, metric=metric)


class PallasStatsBackend:
    """Fused Pallas kernels (``repro.kernels``): the distance tile and the
    arm statistics are computed in one VMEM-resident pass; cache-served
    SWAP rounds hit the dedicated ``swap_g_from_cache_kernel``.

    The leader control variate (``lead`` is an arm index) needs the leader
    arm's g-row over the batch — the kernels take it as an input instead
    of materialising the full g block — so it is derived from one extra
    pairwise row: a ledger-neutral O(B) add, since evaluation accounting
    lives in ``adaptive_search``'s ``count_fn``, not in the stats path.
    With ``lead=None`` (baseline="none", the default) that extra kernel
    launch is skipped entirely and the cross output is zeros.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None, tm: int = 128):
        self.interpret = interpret
        self.tm = tm

    def pairwise(self, x, y, *, metric):
        from repro.kernels import ops
        return ops.pairwise_distance(x, y, metric=metric,
                                     interpret=self.interpret)

    # -- BUILD ----------------------------------------------------------
    def build_stats(self, data, ref_idx, dnear_b, w, lead, *, metric):
        from repro.kernels import ops
        y = data[ref_idx]
        if lead is None:
            lead_g = None
        else:
            dl = ops.pairwise_distance(data[lead][None, :], y, metric=metric,
                                       interpret=self.interpret)[0]
            lead_g = jnp.where(jnp.isinf(dnear_b), dl,
                               jnp.minimum(dl - dnear_b, 0.0)) * w
        return ops.build_g_stats(data, y, dnear_b, w, lead_g, metric=metric,
                                 tm=self.tm, interpret=self.interpret)

    def build_stats_from_d(self, dxy, dnear_b, w, lead):
        # No distance pass to fuse — cache-served BUILD stats are plain
        # vector math, shared with the jnp backend.
        return JnpStatsBackend.build_stats_from_d(self, dxy, dnear_b, w,
                                                  lead)

    # -- SWAP -----------------------------------------------------------
    def _swap_lead_g(self, dl, d1_b, d2_b, assign_b, m_l):
        base_l = jnp.minimum(dl, d1_b) - d1_b
        corr_l = jnp.minimum(dl, d2_b) - jnp.minimum(dl, d1_b)
        return base_l + (assign_b == m_l).astype(dl.dtype) * corr_l

    def swap_stats(self, data, ref_idx, d1_b, d2_b, assign_b, w, k, lead,
                   *, metric):
        from repro.kernels import ops
        n = data.shape[0]
        y = data[ref_idx]
        if lead is None:
            lead_g = None
        else:
            m_l, x_l = lead // n, lead % n
            dl = ops.pairwise_distance(data[x_l][None, :], y, metric=metric,
                                       interpret=self.interpret)[0]
            lead_g = self._swap_lead_g(dl, d1_b, d2_b, assign_b, m_l)
        s, q, c = ops.swap_g_stats(data, y, d1_b, d2_b, assign_b, w, k,
                                   lead_g, metric=metric, tm=self.tm,
                                   interpret=self.interpret)
        return s.reshape(-1), q.reshape(-1), c.reshape(-1)

    def swap_stats_from_d(self, dxy, d1_b, d2_b, assign_b, w, k, lead):
        from repro.kernels import ops
        n = dxy.shape[0]
        if lead is None:
            lead_g = None
        else:
            m_l, x_l = lead // n, lead % n
            lead_g = self._swap_lead_g(dxy[x_l], d1_b, d2_b, assign_b, m_l)
        s, q, c = ops.swap_g_stats_cached(dxy, d1_b, d2_b, assign_b, w, k,
                                          lead_g, tm=self.tm,
                                          interpret=self.interpret)
        return s.reshape(-1), q.reshape(-1), c.reshape(-1)

    # -- streaming contract ---------------------------------------------
    def _stream_ok(self, d: int, metric: str) -> bool:
        # The streaming kernels hold both operand tiles feature-resident
        # (g-statistics are not additive across feature chunks), so very
        # wide inputs fall back to the tiled jnp walk.
        from repro.kernels import ops
        return metric in ops.KERNEL_METRICS and -(-d // 128) * 128 <= ops.DK_MAX

    def stream_build_sums(self, data, dnear, *, metric):
        from repro.kernels import ops
        if not self._stream_ok(data.shape[1], metric):
            return _stream_build_sums_jnp(data, dnear, metric=metric)
        s, _, _ = ops.stream_build_g_stats(data, data, dnear, metric=metric,
                                           interpret=self.interpret)
        return s

    def stream_swap_sums(self, data, d1, d2, assign, k, *, metric):
        from repro.kernels import ops
        if not self._stream_ok(data.shape[1], metric):
            return _stream_swap_sums_jnp(data, d1, d2, assign, k,
                                         metric=metric)
        s, _, _ = ops.stream_swap_g_stats(data, data, d1, d2, assign, None,
                                          k, metric=metric,
                                          interpret=self.interpret)
        return s.reshape(-1)

    def top2(self, x, med_pts, *, metric):
        from repro.kernels import ops
        if not self._stream_ok(x.shape[1], metric):
            return _stream_top2_jnp(x, med_pts, metric=metric)
        return ops.stream_top2(x, med_pts, metric=metric,
                               interpret=self.interpret)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Any] = {}


def register_stats_backend(name: str, backend) -> None:
    """Register a stats backend instance under ``name`` (see the module
    docstring for the method contract)."""
    _BACKENDS[name] = backend


def get_stats_backend(name: str):
    if name not in _BACKENDS:
        raise KeyError(f"unknown stats backend {name!r}; "
                       f"have {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def available_stats_backends():
    return sorted(_BACKENDS)


register_stats_backend("jnp", JnpStatsBackend())
register_stats_backend("pallas", PallasStatsBackend())


def resolve_stats_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a ``backend=`` argument to a registered backend name.

    ``"auto"`` (or None) routes kernel-implemented metrics through Pallas
    only on TPU — the kernels are written against TPU tiling (128-lane
    padding, MXU-shaped contractions) and are not validated under other
    lowerings; interpret-mode Pallas on CPU is correct but orders of
    magnitude slower.  Everything else falls back to jnp (XLA compiles
    that well on every backend).  An explicit ``"pallas"`` with a metric
    the kernels don't implement is an error.
    """
    from repro.kernels.ops import KERNEL_METRICS
    if backend in (None, "auto"):
        if metric in KERNEL_METRICS and jax.default_backend() == "tpu":
            return "pallas"
        return "jnp"
    get_stats_backend(backend)  # raises KeyError for unknown names
    if backend == "pallas" and metric not in KERNEL_METRICS:
        raise ValueError(f"metric {metric!r} has no Pallas kernel "
                         f"(kernel metrics: {list(KERNEL_METRICS)}); "
                         f"use backend='jnp'")
    return backend


# ---------------------------------------------------------------------------
# FitContext — per-fit state, explicit instead of instance-resident
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FitContext:
    """Everything one ``BanditPAM.fit`` call threads between phases.

    Historically this state (``_pic`` / ``_perm`` / ``_dwarm`` /
    ``_free_rounds``) leaked onto the estimator instance, so a second
    ``fit`` inherited stale cache state and pre-fit attribute access
    crashed.  Holding it here makes the engine re-entrant: the instance
    carries configuration only.

    ``mode`` selects the cache regime:

    * ``"none"`` — no distance cache; every round is fresh.
    * ``"warm"`` — paper App 2.2: a fixed permutation plus an upfront warm
      block of its first ``free_rounds`` column batches (static; no
      write-through).
    * ``"pic"``  — BanditPAM++ permutation-invariant cache, device-resident
      and width-bounded: ``cache`` is a :class:`~repro.core.pic_cache.PicCache`
      ring of ``cache_width`` columns with round recycling; searches
      write fresh blocks through from inside the bandit loop, and rounds
      whose slot was recycled fall back to fresh recomputation.

    ``batch > 0`` marks a BATCHED context (``BanditPAM.fit_batch``): the
    array fields gain a leading ``[batch]`` fit axis (``cache.cols`` is
    ``[batch, n, W·B]``, ``perm_idx`` is ``[batch, W·B]``, ...) and the
    batch-only fields below are populated — per-fit validity masks for
    padded ragged datasets, per-fit logical n, per-fit ``log(1/δ)`` terms
    (δ depends on n, which is ragged), and the pre-tiled per-search
    reference-permutation layouts that the single-fit path would generate
    inside the search from its RNG chain (they must be data, not trace
    constants, once n is ragged).
    """

    mode: str                              # "none" | "warm" | "pic"
    backend: str                           # registered stats-backend name
    perm: Optional[jnp.ndarray] = None     # [n] fixed reference permutation
    perm_idx: Optional[jnp.ndarray] = None  # [W·B] tiled permutation prefix
    perm_w: Optional[jnp.ndarray] = None   # [W·B] {0,1} padding weights
    cache: Optional[PicCache] = None       # bounded PIC column ring ("pic");
    #                                        capacity W = cols.shape[1] // B
    dwarm: Optional[jnp.ndarray] = None    # [n, C] warm columns ("warm")
    free_rounds: int = 0                   # static warm-block rounds ("warm")
    warm_medoids: Optional[jnp.ndarray] = None  # [k] int32 BUILD bypass:
    #   when set, ``fit`` skips BUILD entirely and SWAP starts from these
    #   indices (the serving layer's incremental-refit entry; build ledger
    #   records 0 and the BUILD subkeys are never drawn)
    # -- batched multi-fit fields (leading [batch] axis when batch > 0) --
    batch: int = 0                         # fit count; 0 = single-fit context
    valid: Optional[jnp.ndarray] = None    # [batch, n] bool row-validity
    n_valid: Optional[jnp.ndarray] = None  # [batch] int32 logical n per fit
    log_build: Optional[jnp.ndarray] = None   # [batch] f32 log(1/δ_build)
    log_swap: Optional[jnp.ndarray] = None    # [batch] f32 log(1/δ_swap)
    spidx_build: Optional[jnp.ndarray] = None  # [batch, k, R·B] or
    #                                            [batch, R·B] search layouts
    spidx_swap: Optional[jnp.ndarray] = None   # [batch, T, R·B] or
    #                                            [batch, R·B]
    spw: Optional[jnp.ndarray] = None      # [batch, R·B] {0,1} weights
