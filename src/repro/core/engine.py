"""StatsBackend — the one seam between the bandit drivers and the
g-statistics compute paths.

Before this layer existed the Pallas kernels (``repro.kernels.ops``) were
exercised only by tests and benchmarks while the real fit path ran
pure-jnp statistics.  ``StatsBackend`` unifies the three g-statistics
paths behind one contract so the drivers are backend-agnostic and the
kernels power the actual fit:

* ``"jnp"``    — jit'd XLA math (``_build_g`` / ``_swap_batch_stats``);
  works for every registered metric, including user callables and
  ``"precomputed"``.
* ``"pallas"`` — the fused TPU kernels (``kernels.ops.build_g_stats`` /
  ``swap_g_stats`` for fresh rounds, ``swap_g_stats_cached`` for rounds
  served from the device-resident PIC column cache).  Kernel-implemented
  metrics only; interpret-mode on CPU.
* cache-served — both backends read warm rounds from a resident distance
  block via the ``*_from_d`` methods (the Pallas side uses the dedicated
  cached-stats kernel for SWAP; BUILD stats from a resident block are
  distance-free vector math and share the jnp formula).

Selection is by name (``backend="auto" | "pallas" | "jnp"`` on
``BanditPAM`` / ``repro.api.KMedoids``); the registry is open so an
out-of-tree backend (a GPU Triton port, say) is one ``register_stats_backend``
call.  ``"auto"`` picks Pallas for kernel-implemented metrics on a real
accelerator and jnp everywhere else — interpret-mode Pallas on CPU is
correct but slow, so it must be requested explicitly.

``FitContext`` carries every piece of per-fit state (RNG key, the fixed
reference permutation, the device-resident PIC cache buffer and its
high-water mark) that historically leaked onto the ``BanditPAM`` instance,
making ``fit`` re-entrant.

The shared g-statistics math (``_build_g``, ``_swap_terms``,
``_swap_batch_stats``), the medoid cache, and the exact loss live here so
``core.banditpam``, ``core.pam``, and ``core.distributed`` all draw from
one definition.  Backends are collective-free by contract: the sharded
driver (``core.distributed``) calls ``pairwise`` + ``*_stats_from_d`` on
shard-local blocks inside ``shard_map`` and composes the cross-shard
``psum`` itself, so every registered backend reaches the distributed path
unchanged.  See docs/design.md for the numbered hardware adaptations.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import get_metric, pairwise
from .pic_cache import (PicCache, cache_read_or_write, carry_valid,  # noqa: F401
                        fresh_positions, make_cache,  # noqa: F401
                        resolve_cache_rounds)  # noqa: F401

_EXACT_CHUNK = 512  # reference-chunk size for exact fallback passes


# ---------------------------------------------------------------------------
# Shared cache / loss helpers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric",))
def medoid_cache(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d1 (nearest-medoid dist), d2 (second nearest), assignment; [n] each."""
    dmat = get_metric(metric)(data, data[medoids])          # [n, k]
    assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    d1 = jnp.min(dmat, axis=1)
    dmat2 = dmat.at[jnp.arange(dmat.shape[0]), assign].set(jnp.inf)
    d2 = jnp.min(dmat2, axis=1)
    return d1, d2, assign


@functools.partial(jax.jit, static_argnames=("metric",))
def total_loss(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str,
               w=None) -> jnp.ndarray:
    """Sum of nearest-medoid dissimilarities.  ``w`` (optional bool [n])
    masks rows out of the sum — the batched multi-fit path scores padded
    datasets with it (``jnp.where``, not a multiply, so NaN rows from
    degenerate pad points cannot poison the loss)."""
    dmat = get_metric(metric)(data, data[medoids])
    dmin = jnp.min(dmat, axis=1)
    if w is None:
        return jnp.sum(dmin)
    return jnp.sum(jnp.where(w, dmin, 0.0))


def _ref_chunks(n_ref: int, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static index/weight tiling of [0, n_ref) into equal chunks."""
    n_chunks = -(-n_ref // chunk)
    idx = np.arange(n_chunks * chunk)
    w = (idx < n_ref).astype(np.float32)
    idx = np.minimum(idx, n_ref - 1)
    return idx.reshape(n_chunks, chunk), w.reshape(n_chunks, chunk)


def exact_build_means(be, data, dnear, *, metric: str) -> jnp.ndarray:
    """Exact BUILD objective over the full reference set (Algorithm 1
    lines 13–15 fallback): per-arm mean g, [n].  Chunked scan through the
    backend's pairwise path so the resident block stays bounded — the one
    definition shared by the single-device and sharded drivers."""
    n = data.shape[0]
    idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

    def body(acc, iw):
        i, w_i = iw
        dxy = be.pairwise(data, data[i], metric=metric)
        s, _, _ = be.build_stats_from_d(dxy, dnear[i], w_i, None)
        return acc + s, None

    sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32), (idx, w))
    return sums / n


def exact_swap_means(be, data, d1, d2, assign, k: int, *, metric: str
                     ) -> jnp.ndarray:
    """Exact SWAP objective over the flattened (medoid, candidate) arm
    set: per-arm mean g, [k·n]; same chunked backend-routed form as
    :func:`exact_build_means`."""
    n = data.shape[0]
    idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

    def body(acc, iw):
        i, w_i = iw
        dxy = be.pairwise(data, data[i], metric=metric)
        s, _, _ = be.swap_stats_from_d(dxy, d1[i], d2[i], assign[i], w_i, k,
                                       None)
        return acc + s, None

    sums, _ = jax.lax.scan(body, jnp.zeros((k * n,), jnp.float32), (idx, w))
    return sums / n


# ---------------------------------------------------------------------------
# g-statistics math (the Eq. 6 / Eq. 12 forms shared by every caller)
# ---------------------------------------------------------------------------

def _build_g(dxy: jnp.ndarray, dnear_b: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6 with the Eq. 4 special-case for the first assignment."""
    dn = dnear_b[None, :]
    return jnp.where(jnp.isinf(dn), dxy, jnp.minimum(dxy - dn, 0.0))


def _swap_terms(dxy: jnp.ndarray, d1_b: jnp.ndarray, d2_b: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    base = jnp.minimum(dxy, d1_b[None, :]) - d1_b[None, :]
    corr = jnp.minimum(dxy, d2_b[None, :]) - jnp.minimum(dxy, d1_b[None, :])
    return base, corr


def _swap_batch_stats(dxy, d1_b, d2_b, a_b, w, k, lead=None):
    """Per-arm (m·n + x) sums, square-sums (and optional leader cross-sums)
    over a reference batch.

    g = base + 1[assign==m]·corr  ⇒
      Σ g        = Σ base + Σ_{y∈C_m} corr
      Σ g²       = Σ base² + Σ_{y∈C_m} (2·base·corr + corr²)
      Σ g·g_lead = Σ base·g_lead + Σ_{y∈C_m} corr·g_lead
    The C_m-restricted sums are one-hot matmuls (MXU-shaped).
    """
    n = dxy.shape[0]
    base, corr = _swap_terms(dxy, d1_b, d2_b)
    # weights are {0,1} (padding mask), so w² = w and masking base once is
    # enough for every product below.
    base = base * w[None, :]
    onehot = jax.nn.one_hot(a_b, k, dtype=dxy.dtype) * w[:, None]   # [B, k]
    sums = jnp.sum(base, axis=1)[None, :] + (corr @ onehot).T       # [k, n]
    sq_base = jnp.sum(base * base, axis=1)
    sq_cross = 2.0 * base * corr + corr * corr
    sqsums = sq_base[None, :] + (sq_cross @ onehot).T
    if lead is None:
        return sums.reshape(-1), sqsums.reshape(-1)
    m_l, x_l = lead // n, lead % n
    g_lead = base[x_l] + onehot[:, m_l] * corr[x_l]                 # [B], w-masked
    cross = (base @ g_lead)[None, :] + ((corr * g_lead[None, :]) @ onehot).T
    return sums.reshape(-1), sqsums.reshape(-1), cross.reshape(-1)


# ---------------------------------------------------------------------------
# Device-resident PIC cache primitives: extracted to
# ``repro.core.pic_cache`` (bounded width + round recycling) and
# re-exported from the top of this module for the drivers and historical
# importers.
def counted_dispatch(fn, dispatches: Dict[str, int], phase: str):
    """Wrap a compiled phase callable so every driver-level dispatch is
    COUNTED at the call site — ``FitReport.dispatches_by_phase`` is a
    measurement, not a self-reported constant.  A refactor that
    re-introduces a per-selection host loop shows up in the recorded
    count (and trips ``benchmarks/distributed_bench.py``'s single-
    dispatch BUILD assertion) instead of being silently papered over."""
    def call(*args, **kw):
        dispatches[phase] = dispatches.get(phase, 0) + 1
        return fn(*args, **kw)
    return call


# ---------------------------------------------------------------------------
# StatsBackend implementations
# ---------------------------------------------------------------------------

class JnpStatsBackend:
    """Pure-XLA statistics: any registered metric, any device."""

    name = "jnp"

    def pairwise(self, x, y, *, metric):
        # The jit'd entrypoint: inlined when already inside a trace, and
        # compiled (not op-by-op eager) for eager callers like the
        # chunked predict path.
        return pairwise(x, y, metric=metric)

    # -- BUILD ----------------------------------------------------------
    def build_stats(self, data, ref_idx, dnear_b, w, lead, *, metric):
        """Fused fresh-round BUILD stats: (Σg, Σg², Σg·g_lead), [n] each."""
        return self.build_stats_from_d(
            get_metric(metric)(data, data[ref_idx]), dnear_b, w, lead)

    def build_stats_from_d(self, dxy, dnear_b, w, lead):
        """BUILD stats from a resident distance block (cache-served).
        ``lead=None`` skips the leader cross-sum (baseline="none")."""
        g = _build_g(dxy, dnear_b) * w[None, :]                     # [n, B]
        cross = (jnp.zeros((g.shape[0],), g.dtype) if lead is None
                 else g @ g[lead])
        return jnp.sum(g, axis=1), jnp.sum(g * g, axis=1), cross

    # -- SWAP (FastPAM1 fused form) -------------------------------------
    def swap_stats(self, data, ref_idx, d1_b, d2_b, assign_b, w, k, lead,
                   *, metric):
        """Fused fresh-round SWAP stats, flattened over the (m, x) arm set."""
        return self.swap_stats_from_d(get_metric(metric)(data, data[ref_idx]),
                                      d1_b, d2_b, assign_b, w, k, lead)

    def swap_stats_from_d(self, dxy, d1_b, d2_b, assign_b, w, k, lead):
        """SWAP stats from a resident distance block (cache-served)."""
        if lead is None:
            s, q = _swap_batch_stats(dxy, d1_b, d2_b, assign_b, w, k)
            return s, q, jnp.zeros_like(s)
        return _swap_batch_stats(dxy, d1_b, d2_b, assign_b, w, k, lead=lead)


class PallasStatsBackend:
    """Fused Pallas kernels (``repro.kernels``): the distance tile and the
    arm statistics are computed in one VMEM-resident pass; cache-served
    SWAP rounds hit the dedicated ``swap_g_from_cache_kernel``.

    The leader control variate (``lead`` is an arm index) needs the leader
    arm's g-row over the batch — the kernels take it as an input instead
    of materialising the full g block — so it is derived from one extra
    pairwise row: a ledger-neutral O(B) add, since evaluation accounting
    lives in ``adaptive_search``'s ``count_fn``, not in the stats path.
    With ``lead=None`` (baseline="none", the default) that extra kernel
    launch is skipped entirely and the cross output is zeros.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None, tm: int = 128):
        self.interpret = interpret
        self.tm = tm

    def pairwise(self, x, y, *, metric):
        from repro.kernels import ops
        return ops.pairwise_distance(x, y, metric=metric,
                                     interpret=self.interpret)

    # -- BUILD ----------------------------------------------------------
    def build_stats(self, data, ref_idx, dnear_b, w, lead, *, metric):
        from repro.kernels import ops
        y = data[ref_idx]
        if lead is None:
            lead_g = None
        else:
            dl = ops.pairwise_distance(data[lead][None, :], y, metric=metric,
                                       interpret=self.interpret)[0]
            lead_g = jnp.where(jnp.isinf(dnear_b), dl,
                               jnp.minimum(dl - dnear_b, 0.0)) * w
        return ops.build_g_stats(data, y, dnear_b, w, lead_g, metric=metric,
                                 tm=self.tm, interpret=self.interpret)

    def build_stats_from_d(self, dxy, dnear_b, w, lead):
        # No distance pass to fuse — cache-served BUILD stats are plain
        # vector math, shared with the jnp backend.
        return JnpStatsBackend.build_stats_from_d(self, dxy, dnear_b, w,
                                                  lead)

    # -- SWAP -----------------------------------------------------------
    def _swap_lead_g(self, dl, d1_b, d2_b, assign_b, m_l):
        base_l = jnp.minimum(dl, d1_b) - d1_b
        corr_l = jnp.minimum(dl, d2_b) - jnp.minimum(dl, d1_b)
        return base_l + (assign_b == m_l).astype(dl.dtype) * corr_l

    def swap_stats(self, data, ref_idx, d1_b, d2_b, assign_b, w, k, lead,
                   *, metric):
        from repro.kernels import ops
        n = data.shape[0]
        y = data[ref_idx]
        if lead is None:
            lead_g = None
        else:
            m_l, x_l = lead // n, lead % n
            dl = ops.pairwise_distance(data[x_l][None, :], y, metric=metric,
                                       interpret=self.interpret)[0]
            lead_g = self._swap_lead_g(dl, d1_b, d2_b, assign_b, m_l)
        s, q, c = ops.swap_g_stats(data, y, d1_b, d2_b, assign_b, w, k,
                                   lead_g, metric=metric, tm=self.tm,
                                   interpret=self.interpret)
        return s.reshape(-1), q.reshape(-1), c.reshape(-1)

    def swap_stats_from_d(self, dxy, d1_b, d2_b, assign_b, w, k, lead):
        from repro.kernels import ops
        n = dxy.shape[0]
        if lead is None:
            lead_g = None
        else:
            m_l, x_l = lead // n, lead % n
            lead_g = self._swap_lead_g(dxy[x_l], d1_b, d2_b, assign_b, m_l)
        s, q, c = ops.swap_g_stats_cached(dxy, d1_b, d2_b, assign_b, w, k,
                                          lead_g, tm=self.tm,
                                          interpret=self.interpret)
        return s.reshape(-1), q.reshape(-1), c.reshape(-1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Any] = {}


def register_stats_backend(name: str, backend) -> None:
    """Register a stats backend instance under ``name`` (see the module
    docstring for the method contract)."""
    _BACKENDS[name] = backend


def get_stats_backend(name: str):
    if name not in _BACKENDS:
        raise KeyError(f"unknown stats backend {name!r}; "
                       f"have {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def available_stats_backends():
    return sorted(_BACKENDS)


register_stats_backend("jnp", JnpStatsBackend())
register_stats_backend("pallas", PallasStatsBackend())


def resolve_stats_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a ``backend=`` argument to a registered backend name.

    ``"auto"`` (or None) routes kernel-implemented metrics through Pallas
    only on TPU — the kernels are written against TPU tiling (128-lane
    padding, MXU-shaped contractions) and are not validated under other
    lowerings; interpret-mode Pallas on CPU is correct but orders of
    magnitude slower.  Everything else falls back to jnp (XLA compiles
    that well on every backend).  An explicit ``"pallas"`` with a metric
    the kernels don't implement is an error.
    """
    from repro.kernels.ops import KERNEL_METRICS
    if backend in (None, "auto"):
        if metric in KERNEL_METRICS and jax.default_backend() == "tpu":
            return "pallas"
        return "jnp"
    get_stats_backend(backend)  # raises KeyError for unknown names
    if backend == "pallas" and metric not in KERNEL_METRICS:
        raise ValueError(f"metric {metric!r} has no Pallas kernel "
                         f"(kernel metrics: {list(KERNEL_METRICS)}); "
                         f"use backend='jnp'")
    return backend


# ---------------------------------------------------------------------------
# FitContext — per-fit state, explicit instead of instance-resident
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FitContext:
    """Everything one ``BanditPAM.fit`` call threads between phases.

    Historically this state (``_pic`` / ``_perm`` / ``_dwarm`` /
    ``_free_rounds``) leaked onto the estimator instance, so a second
    ``fit`` inherited stale cache state and pre-fit attribute access
    crashed.  Holding it here makes the engine re-entrant: the instance
    carries configuration only.

    ``mode`` selects the cache regime:

    * ``"none"`` — no distance cache; every round is fresh.
    * ``"warm"`` — paper App 2.2: a fixed permutation plus an upfront warm
      block of its first ``free_rounds`` column batches (static; no
      write-through).
    * ``"pic"``  — BanditPAM++ permutation-invariant cache, device-resident
      and width-bounded: ``cache`` is a :class:`~repro.core.pic_cache.PicCache`
      ring of ``cache_width`` columns with round recycling; searches
      write fresh blocks through from inside the bandit loop, and rounds
      whose slot was recycled fall back to fresh recomputation.

    ``batch > 0`` marks a BATCHED context (``BanditPAM.fit_batch``): the
    array fields gain a leading ``[batch]`` fit axis (``cache.cols`` is
    ``[batch, n, W·B]``, ``perm_idx`` is ``[batch, W·B]``, ...) and the
    batch-only fields below are populated — per-fit validity masks for
    padded ragged datasets, per-fit logical n, per-fit ``log(1/δ)`` terms
    (δ depends on n, which is ragged), and the pre-tiled per-search
    reference-permutation layouts that the single-fit path would generate
    inside the search from its RNG chain (they must be data, not trace
    constants, once n is ragged).
    """

    mode: str                              # "none" | "warm" | "pic"
    backend: str                           # registered stats-backend name
    perm: Optional[jnp.ndarray] = None     # [n] fixed reference permutation
    perm_idx: Optional[jnp.ndarray] = None  # [W·B] tiled permutation prefix
    perm_w: Optional[jnp.ndarray] = None   # [W·B] {0,1} padding weights
    cache: Optional[PicCache] = None       # bounded PIC column ring ("pic");
    #                                        capacity W = cols.shape[1] // B
    dwarm: Optional[jnp.ndarray] = None    # [n, C] warm columns ("warm")
    free_rounds: int = 0                   # static warm-block rounds ("warm")
    warm_medoids: Optional[jnp.ndarray] = None  # [k] int32 BUILD bypass:
    #   when set, ``fit`` skips BUILD entirely and SWAP starts from these
    #   indices (the serving layer's incremental-refit entry; build ledger
    #   records 0 and the BUILD subkeys are never drawn)
    # -- batched multi-fit fields (leading [batch] axis when batch > 0) --
    batch: int = 0                         # fit count; 0 = single-fit context
    valid: Optional[jnp.ndarray] = None    # [batch, n] bool row-validity
    n_valid: Optional[jnp.ndarray] = None  # [batch] int32 logical n per fit
    log_build: Optional[jnp.ndarray] = None   # [batch] f32 log(1/δ_build)
    log_swap: Optional[jnp.ndarray] = None    # [batch] f32 log(1/δ_swap)
    spidx_build: Optional[jnp.ndarray] = None  # [batch, k, R·B] or
    #                                            [batch, R·B] search layouts
    spidx_swap: Optional[jnp.ndarray] = None   # [batch, T, R·B] or
    #                                            [batch, R·B]
    spw: Optional[jnp.ndarray] = None      # [batch, R·B] {0,1} weights
