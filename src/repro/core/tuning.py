"""Backend-aware tile tuner for the streaming g-stats megakernel.

The streaming kernels (``repro.kernels.stream_g``) and their jnp
equivalents walk the reference set in tiles; three knobs shape the walk:

* ``tm`` — candidate-tile rows (one grid program owns a [tm, ·] strip).
* ``tb`` — reference-tile width.  **Pinned to ``REF_TILE`` (512, the
  engine's historical ``_EXACT_CHUNK``) on every parity-checked path**:
  the per-arm accumulation order is "reduce one tb-wide tile, then add
  tiles in walk order", so changing ``tb`` regroups the f32 adds and
  forfeits bit-parity with the ledger fixtures.  It is a knob for
  throwaway sweeps only.
* ``dk`` — feature-axis residency budget.  The streaming kernels hold
  both operand tiles ([tm, d] and [tb, d]) in VMEM for the whole walk;
  feature dims past ``dk`` fall back to the tiled-jnp path (g is not
  additive across feature chunks, so unlike ``pairwise_distance`` the
  fused kernels cannot split d).

``resolve_tile_config`` is the single resolution point, keyed on
``(n, d, k, device kind, backend)``.  It consults a measured ledger
first — ``observe()`` records ``FitReport.wall_by_phase`` (or any
benchmark wall) against the config that produced it, and subsequent
resolves for the same shape bucket return the fastest recorded config —
and falls back to a VMEM-budget heuristic when nothing has been
measured.  ``BanditPAM.fit`` feeds the ledger automatically;
``benchmarks/megakernel_bench.py`` sweeps ``candidates()`` to seed it.

The ledger is in-process state (a dict), deliberately: tile timing is
device-local and a persisted cache would go stale across
driver/topology changes.  Serving processes warm it once at startup.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import jax

# Reference-tile width every parity-checked streaming path is pinned to.
# MUST stay equal to repro.core.engine._EXACT_CHUNK (asserted there): the
# jnp scan chunks and the kernel grid walk share these boundaries so both
# backends accumulate per-arm sums in the same order.
REF_TILE = 512

# Per-core VMEM budget the heuristic packs operand tiles into.  Real TPU
# cores have ~64–128 MiB; staying near 16 MiB leaves room for the
# pipeline's double buffering (two in-flight copies of every operand
# tile) plus output blocks.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

_TM_CANDIDATES = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Resolved tile sizes for one streaming dispatch."""

    tm: int             # candidate-tile rows
    tb: int = REF_TILE  # reference-tile width (parity-pinned default)
    dk: int = 8192      # max resident feature width (lane multiple)


def _bucket(v: int) -> int:
    """Power-of-two shape bucket: tile choice is insensitive to exact n."""
    return 1 << max(int(v) - 1, 0).bit_length()


def shape_key(n: int, d: int, k: int, device_kind: Optional[str] = None,
              backend: str = "jnp") -> Tuple:
    if device_kind is None:
        device_kind = jax.default_backend()
    return (_bucket(n), _bucket(d), _bucket(k), device_kind, backend)


# measured ledger: shape_key -> {TileConfig: best wall seconds}
_LEDGER: Dict[Tuple, Dict[TileConfig, float]] = {}


def heuristic(n: int, d: int, k: int, device_kind: Optional[str] = None,
              backend: str = "jnp") -> TileConfig:
    """VMEM-budget default: the largest ``tm`` whose resident set
    (x-tile + y-tile + stat blocks, f32) fits the budget.  On CPU the
    Pallas kernels run in interpret mode where bigger tiles only grow
    the emulated working set, so ``tm`` stays at the floor."""
    if device_kind is None:
        device_kind = jax.default_backend()
    d_pad = -(-max(int(d), 1) // 128) * 128
    kp = -(-max(int(k), 1) // 128) * 128
    if backend == "pallas" and device_kind == "cpu":
        return TileConfig(tm=_TM_CANDIDATES[0], dk=d_pad)
    tm = _TM_CANDIDATES[0]
    for cand in _TM_CANDIDATES:
        if cand > max(int(n), 1):
            break
        resident = 4 * (cand * d_pad + REF_TILE * d_pad
                        + 3 * cand * kp)          # x + y + stat blocks
        if resident <= VMEM_BUDGET_BYTES:
            tm = cand
    return TileConfig(tm=tm, dk=d_pad if d_pad <= 8192 else 8192)


def candidates(n: int, d: int, k: int, device_kind: Optional[str] = None,
               backend: str = "jnp") -> Iterable[TileConfig]:
    """Sweepable configs for ``observe()`` feeders (benchmarks, warmup)."""
    base = heuristic(n, d, k, device_kind, backend)
    seen = []
    for tm in _TM_CANDIDATES:
        if tm <= max(int(n), 1) * 2:
            cfg = dataclasses.replace(base, tm=tm)
            if cfg not in seen:
                seen.append(cfg)
    return seen or [base]


def observe(n: int, d: int, k: int, config: TileConfig,
            wall_by_phase: Dict[str, float],
            device_kind: Optional[str] = None,
            backend: str = "jnp") -> None:
    """Record a measured wall (sum of the distance-phase walls) for the
    config that produced it.  Best-of is kept per config so noisy reps
    only ever improve the estimate."""
    wall = float(sum(wall_by_phase.get(p, 0.0)
                     for p in ("build", "swap", "loss", "stream")))
    if wall <= 0.0:
        return
    key = shape_key(n, d, k, device_kind, backend)
    best = _LEDGER.setdefault(key, {})
    best[config] = min(best.get(config, float("inf")), wall)


def resolve_tile_config(n: int, d: int, k: int,
                        device_kind: Optional[str] = None,
                        backend: str = "jnp") -> TileConfig:
    """Measured-best config for the shape bucket, else the heuristic."""
    key = shape_key(n, d, k, device_kind, backend)
    measured = _LEDGER.get(key)
    if measured:
        return min(measured.items(), key=lambda kv: kv[1])[0]
    return heuristic(n, d, k, device_kind, backend)


def ledger_snapshot() -> Dict[Tuple, Dict[TileConfig, float]]:
    """Copy of the measured ledger (benchmark/CI introspection)."""
    return {k: dict(v) for k, v in _LEDGER.items()}


def clear_ledger() -> None:
    _LEDGER.clear()
