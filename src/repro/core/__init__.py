# repro.core — the paper's contribution: BanditPAM k-medoids via
# multi-armed bandits, plus the exact PAM oracles and quality baselines.
from .adaptive import SearchResult, adaptive_search
from .report import FitReport
from .engine import (FitContext, available_stats_backends,
                     get_stats_backend, register_stats_backend,
                     resolve_stats_backend)
from .banditpam import BanditPAM, FitResult, medoid_cache, total_loss
from .distances import (attach_index, available_metrics, get_metric, pairwise,
                        register_metric, resolve_metric)
from .onebatch import onebatchpam
from .pam import PAMResult, pam
from .baselines import BaselineResult, clara, clarans, fasterpam, voronoi_iteration
from . import datasets

__all__ = [
    "SearchResult", "adaptive_search", "BanditPAM", "FitReport", "FitResult",
    "FitContext", "available_stats_backends", "get_stats_backend",
    "register_stats_backend", "resolve_stats_backend",
    "medoid_cache", "total_loss", "attach_index", "available_metrics",
    "get_metric", "pairwise", "register_metric", "resolve_metric",
    "onebatchpam",
    "PAMResult", "pam", "BaselineResult", "clara", "clarans", "fasterpam",
    "voronoi_iteration", "datasets",
]
