# repro.core — the paper's contribution: BanditPAM k-medoids via
# multi-armed bandits, plus the exact PAM oracles and quality baselines.
from .adaptive import SearchResult, adaptive_search
from .banditpam import BanditPAM, FitResult, medoid_cache, total_loss
from .distances import available_metrics, get_metric, pairwise, register_metric
from .pam import PAMResult, pam
from .baselines import clara, clarans, fasterpam, voronoi_iteration
from . import datasets

__all__ = [
    "SearchResult", "adaptive_search", "BanditPAM", "FitResult",
    "medoid_cache", "total_loss", "available_metrics", "get_metric",
    "pairwise", "register_metric", "PAMResult", "pam", "clara", "clarans",
    "fasterpam", "voronoi_iteration", "datasets",
]
