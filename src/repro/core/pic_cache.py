"""The BanditPAM++ permutation-invariant column (PIC) cache — bounded
width, round recycling, and one layout for the single-device and
mesh-sharded drivers.

The cache stores whole distance columns ``d(·, y)`` for the reference
points consumed by the bandit rounds of one fit.  Because every search
walks the SAME fixed reference permutation, round ``r`` always consumes
the same reference slice, so its column block can be materialised once
and replayed by every later search (BanditPAM++, Tiwari et al. 2023).

Historically the device buffer was preallocated at full width
``[n, n_rounds_max·B]`` — O(n²) floats, which is exactly what stops
``reuse="pic"`` from scaling past ~10⁵ points per host.  This module
bounds it:

* **Bounded width** — the buffer holds at most ``W`` round-blocks
  (``cache_width`` columns, default a few dozen round-batches), so the
  footprint is O(n·W) with ``W ≪ n``.
* **Round recycling** — rounds land in ring slots ``r mod W``; when a
  search materialises a round past the capacity, the slot of the oldest
  resident round is recycled (evicted).  The resident window is always
  the trailing ``[hw − W, hw)`` of the ``hw`` rounds ever materialised.
* **Exact fallback** — a round outside the window is simply recomputed
  fresh (and NOT retained, so the window invariant survives): the
  replayed block is bit-identical to the evicted one, so medoids, loss,
  and the exactness of the ledger are unchanged — only the fresh/cached
  split shifts, which ``fresh_pos`` tracks precisely.

Ledger rule: ``fresh_pos`` accumulates the *effective* (non-padding)
reference positions of every round the fit computed fresh — first
materialisations and evicted-round replays alike — and a fresh
evaluation costs ``n`` per position (a full column, which is what makes
the position free for every arm of every later search that finds it
resident).  Window-served rounds are tallied by ``adaptive_search`` as
cached reads at the algorithmic ``count_fn·B`` rate.

The carried-moment reuse (virtual arms) reads the permutation *prefix*
``[0, c_rounds)`` of the cache; that prefix is resident — and ring slots
are the identity mapping — exactly while ``hw ≤ W``, so the drivers mask
the carry off once recycling has started (``carry_valid``).

Sharded layout (``core.distributed``): the same ring, split over the
mesh's data axes by reference ownership — each shard holds the
``[n, W·b_loc]`` block of the columns its own rows produce (``b_loc =
B / n_shards``), updated from inside ``shard_map`` via
:func:`shard_slot_read_write`; the ``hw``/``fresh_pos`` scalars are
replicated and advanced outside the collective.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["PicCache", "DEFAULT_CACHE_ROUNDS", "resolve_cache_rounds",
           "resolve_batch_cache_rounds", "make_cache",
           "cache_read_or_write", "cache_advance", "shard_slot_read_write",
           "carry_valid", "fresh_positions"]

# Default width cap in round-blocks: generous enough that tier-scale fits
# (n up to a few thousand at B=100) never recycle — their ledgers stay
# bit-identical to the historical unbounded buffer — while keeping the
# footprint O(n·W·B) at large n (3 orders of magnitude under O(n²) at
# n = 10⁵, B = 100).
DEFAULT_CACHE_ROUNDS = 32


class PicCache(NamedTuple):
    """Device-resident cache state threaded through the search carry.

    ``cols`` — the ring of round-column blocks.  Single-device:
    ``[n, W·B]``.  Sharded: ``[n, n_shards·W·b_loc]``, sharded over the
    column axis so each shard owns its own rows' columns.
    ``hw`` — int32, total rounds ever materialised (monotone; the
    resident window is ``[max(hw − W, 0), hw)``).
    ``fresh_pos`` — uint32, cumulative effective reference positions
    computed fresh (materialisations + evicted-round replays); the fresh
    ledger of a search is ``n · Δfresh_pos`` (:func:`fresh_positions`,
    multiplied by ``n`` host-side).
    """

    cols: jnp.ndarray
    hw: jnp.ndarray
    fresh_pos: jnp.ndarray


def resolve_cache_rounds(n_rounds_max: int, batch_size: int,
                         cache_width: Optional[int] = None) -> int:
    """Resolve the ``cache_width`` knob (columns) to a round-block count.

    ``None`` → ``min(n_rounds_max, DEFAULT_CACHE_ROUNDS)``; otherwise the
    width is rounded DOWN to whole round-blocks (the ring recycles whole
    rounds) and clamped to ``[1, n_rounds_max]``.  ``cache_width ≥
    batch_size`` is required — a cache narrower than one round-block can
    never serve a read.
    """
    if cache_width is None:
        return min(n_rounds_max, DEFAULT_CACHE_ROUNDS)
    cache_width = int(cache_width)
    if cache_width < batch_size:
        raise ValueError(
            f"cache_width={cache_width} is narrower than one round-batch "
            f"(batch_size={batch_size}); need cache_width >= batch_size")
    return max(1, min(n_rounds_max, cache_width // batch_size))


def resolve_batch_cache_rounds(ns, batch_size: int,
                               cache_width: Optional[int] = None) -> int:
    """One ring width for a BATCH of padded fits (``fit_batch``): the max
    of each fit's solo-resolved width, so every lane gets at least the
    ring it would have had alone — the bit-parity guarantee of the
    batched path then holds exactly as far as the single-fit one does
    (a fit that would not recycle solo does not recycle in the batch).
    Lanes with smaller n simply leave their trailing slots cold."""
    return max(resolve_cache_rounds(-(-int(n) // batch_size), batch_size,
                                    cache_width) for n in ns)


def make_cache(n_rows: int, block: int, rounds: int) -> PicCache:
    """Fresh all-cold cache: ``rounds`` ring slots of ``block`` columns."""
    return PicCache(cols=jnp.zeros((n_rows, rounds * block), jnp.float32),
                    hw=jnp.int32(0), fresh_pos=jnp.uint32(0))


def shard_slot_read_write(cols: jnp.ndarray, rnd, hw, block: int,
                          compute_fresh):
    """One ring access on a (possibly shard-local) column buffer.

    Serves round ``rnd`` from its ring slot when it lies in the resident
    window ``[hw − W, hw)``; otherwise calls ``compute_fresh() ->
    [rows, block]`` and retains the block only when it is a NEW round
    (``rnd ≥ hw`` — retaining an evicted replay would evict a newer
    round and break the trailing-window invariant).  Returns
    ``(block, cols')``; the caller advances ``hw``.
    """
    W = cols.shape[1] // block
    lo = jnp.maximum(hw - W, 0)
    in_window = jnp.logical_and(rnd >= lo, rnd < hw)
    slot = (rnd % W) * block

    def cached(c):
        return jax.lax.dynamic_slice_in_dim(c, slot, block, 1), c

    def fresh(c):
        dxy = compute_fresh()
        c2 = jax.lax.cond(
            rnd >= hw,
            lambda cc: jax.lax.dynamic_update_slice_in_dim(cc, dxy, slot, 1),
            lambda cc: cc, c)
        return dxy, c2

    return jax.lax.cond(in_window, cached, fresh, cols)


def cache_advance(cache: PicCache, cols, rnd, b_eff,
                  rounds_cap: int) -> PicCache:
    """Post-access bookkeeping shared by every PIC stats path (single
    device and sharded): charge ``b_eff`` fresh positions unless round
    ``rnd`` was served from the resident window, and advance the
    high-water mark past it.  ``cols`` is the (possibly updated) ring
    buffer; ``rounds_cap`` its capacity ``W``.  The one definition of
    the window predicate + ledger rule."""
    lo = jnp.maximum(cache.hw - rounds_cap, 0)
    in_window = jnp.logical_and(rnd >= lo, rnd < cache.hw)
    fresh_pos = cache.fresh_pos + jnp.where(
        in_window, 0, b_eff).astype(jnp.uint32)
    return PicCache(cols, jnp.maximum(cache.hw, rnd + 1), fresh_pos)


def cache_read_or_write(be, data, ref_idx, *, metric: str, batch_size: int,
                        rnd, b_eff, cache: PicCache):
    """One PIC cache access inside a single-device bandit round.

    Serve round ``rnd`` from the ring when resident, else compute the
    ``[n, B]`` block fresh through the backend's pairwise path (written
    through only for new rounds).  ``b_eff`` is the round's effective
    (non-padding) position count — the fresh-ledger increment when the
    block is computed.  Returns ``(dxy, cache')``.
    """
    dxy, cols = shard_slot_read_write(
        cache.cols, rnd, cache.hw, batch_size,
        lambda: be.pairwise(data, data[ref_idx], metric=metric))
    return dxy, cache_advance(cache, cols, rnd, b_eff,
                              cache.cols.shape[1] // batch_size)


def carry_valid(cache: PicCache, block: Optional[int] = None,
                rounds_cap: Optional[int] = None):
    """Whether carried per-arm moments may seed the next search: the
    permutation prefix they were accumulated over is resident (and ring
    slots are the identity mapping) exactly while no round has been
    recycled yet.  The ring capacity is derived from ``block`` (the
    single-device round-block width) or passed as ``rounds_cap`` when
    ``cols`` is the mesh-wide sharded buffer (whose column count is
    ``n_shards·W·b_loc``, not ``W·block``)."""
    W = rounds_cap if rounds_cap is not None else cache.cols.shape[1] // block
    return cache.hw <= W


def fresh_positions(cache0: PicCache, cache1: PicCache):
    """Effective reference positions computed fresh between two cache
    states (new materialisations and evicted-round replays alike — each
    is a full column, i.e. ``n`` distance evaluations).  Returns the
    POSITION count; the drivers multiply by ``n`` on the host, where
    Python integers cannot wrap — a device-side ``n·Δ`` uint32 product
    would overflow in exactly the n ≳ 10⁵ regimes the bounded ring
    targets."""
    return cache1.fresh_pos - cache0.fresh_pos
