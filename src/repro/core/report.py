"""The one fit report every k-medoids solver in this repo emits.

Historically each entrypoint had its own result type (``FitResult`` for
``BanditPAM``, ``PAMResult`` for ``pam``, ``BaselineResult`` for the
baselines) with divergent fields, which made cross-solver comparisons —
the paper's whole point — need per-type glue.  ``FitReport`` collapses
them: the old names remain importable as aliases of this class, so every
solver now returns the same dataclass and the ``repro.api.KMedoids``
facade can treat them interchangeably.

Ledger semantics (the paper's headline metric):

* ``distance_evals`` — FRESH pairwise dissimilarity evaluations the
  algorithm paid for, exactly as the paper counts them.
* ``cached_evals`` — evaluations served from a distance cache (the
  BanditPAM++ PIC engine); zero for cache-less solvers.
* ``evals_by_phase`` — the itemised split.  Keys ending in ``_cached``
  count cache-served work and are excluded from ``distance_evals``;
  everything else is fresh.  Typical keys: ``build``, ``swap``,
  ``cache_warm``, ``build_cached``, ``swap_cached``.

``labels`` (the in-sample cluster assignment) is filled by the facade
after the solve; solvers themselves only need medoids + loss + ledger.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass
class FitReport:
    medoids: np.ndarray
    loss: float
    n_swaps: int = 0
    # False by default: only solvers with an actual convergence criterion
    # (banditpam*, pam/fastpam1, fasterpam, voronoi) set it — budget-
    # exhausting solvers (clara, clarans) honestly report False.
    converged: bool = False
    distance_evals: int = 0
    evals_by_phase: Dict[str, int] = field(default_factory=dict)
    swap_history: List[Tuple[int, int, float]] = field(default_factory=list)
    build_rounds: List[int] = field(default_factory=list)
    swap_exact_fallbacks: int = 0
    cached_evals: int = 0   # evaluations served from a distance cache
    labels: Optional[np.ndarray] = None
    solver: str = ""
    metric: str = ""
    # Wall-clock seconds per phase (e.g. "build" / "swap"), filled by
    # solvers that time their phases (BanditPAM).  Unlike the ledger this
    # is environment-dependent; benchmarks/core_bench.py medians it.
    wall_by_phase: Dict[str, float] = field(default_factory=dict)
    # Driver-level compiled phase-step calls, MEASURED at the call site
    # (``engine.counted_dispatch``, not a self-reported constant): the
    # fused BUILD registers 1 for the whole phase, the stepped baseline
    # one per selection; SWAP registers one step per iteration (the
    # stepped baseline's step internally bundles a few sub-dispatches).
    # benchmarks/distributed_bench.py asserts the sharded BUILD stays 1.
    dispatches_by_phase: Dict[str, int] = field(default_factory=dict)

    def ledger(self) -> Dict[str, object]:
        """The unified fresh/cached distance-evaluation ledger as one dict
        (what ``benchmarks/run.py --json`` serialises per solver)."""
        return {
            "fresh": int(self.distance_evals),
            "cached": int(self.cached_evals),
            "by_phase": {k: int(v) for k, v in self.evals_by_phase.items()},
        }


@dataclass
class BatchFitReport:
    """The result of one batched multi-fit (``BanditPAM.fit_batch`` /
    ``KMedoids.fit_batch``): B independent fits solved in one dispatch
    per phase.

    ``reports`` holds one full per-fit :class:`FitReport` each — medoids,
    loss, and the fresh/cached ledger, bit-identical to what the
    single-fit path would have produced for the same per-fit seed (the
    invariant ``tests/test_multifit.py`` pins).  The batch-level fields
    are what is NOT per-fit:

    * ``dispatches_by_phase`` — measured at the driver call site
      (``engine.counted_dispatch``), for the WHOLE batch: the batched
      engine compiles to one jit per phase, so this reads
      ``{"build": 1, "swap": 1}`` regardless of B (the per-fit reports
      leave theirs empty — a lane inside a batched dispatch has no
      dispatch count of its own).
    * ``wall_by_phase`` — batch wall-clock per phase; divide by
      ``len(batch)`` for the amortised per-fit cost
      (``benchmarks/multifit_bench.py``).
    * ``medoids``/``loss`` — the stacked ``[B, k]`` / ``[B]`` views.
    * ``labels`` — stacked ``[B, n_max]`` in-sample assignments (filled
      by the facade; pad rows carry arbitrary labels — mask with
      ``n_valid``).
    * ``n_valid`` — the logical per-fit n of the (possibly ragged,
      padded) inputs.

    The container is sequence-like: ``len(batch)``, ``batch[i]``, and
    iteration yield the per-fit reports.
    """

    reports: List[FitReport]
    medoids: np.ndarray                     # [B, k]
    loss: np.ndarray                        # [B]
    n_valid: Optional[np.ndarray] = None    # [B] logical n per fit
    labels: Optional[np.ndarray] = None     # [B, n_max]
    solver: str = ""
    metric: str = ""
    wall_by_phase: Dict[str, float] = field(default_factory=dict)
    dispatches_by_phase: Dict[str, int] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.reports)

    def __getitem__(self, i: int) -> FitReport:
        return self.reports[i]

    def __iter__(self) -> Iterator[FitReport]:
        return iter(self.reports)
