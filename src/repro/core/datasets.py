"""Synthetic statistical twins of the paper's datasets.

The container is offline, so MNIST / scRNA / HOC4 are replaced with
generators that match the *statistical regime* the paper relies on:

* ``mnist_like``    — 784-d, 10-mode mixture, coordinates in [0, 1]; arm
  means (mean distance to the dataset) are well spread → BanditPAM's
  assumptions hold (paper §6, Appendix Fig. 2 top-left).
* ``scrna_like``    — 1000-d sparse non-negative "expression counts"
  (log1p of a zero-inflated gamma-Poisson); used with L1 per [37].
* ``scrna_pca_like``— 10-d dense projections with arm means sharply
  concentrated near the minimum — reproduces the Appendix 1.3 violation
  regime where scaling degrades to ~n^1.2.
* ``hoc4_like``     — small-integer structured vectors standing in for
  AST edit-distance features (tree-edit cost ≈ L1 on node-count vectors).
"""

from __future__ import annotations

import numpy as np


def mnist_like(n: int, seed: int = 0, d: int = 784, modes: int = 10,
               zdim: int = 10) -> np.ndarray:
    """Low-dim cluster manifold embedded in 784-d + noise floor.

    Matches the paper's MNIST regime (Appendix Fig. 2 top-left): arm means
    (mean L2 distance to the dataset) spread over ~3x the per-arm sigma, with
    unequal cluster sizes providing a dense core and sparse outskirts.
    """
    rng = np.random.default_rng(seed)
    zc = rng.standard_normal((modes, zdim)) * 4.0          # spread-out centers
    w = rng.dirichlet(np.ones(modes) * 0.5)                # unequal cluster sizes
    z = zc[rng.choice(modes, size=n, p=w)] + rng.standard_normal((n, zdim))
    q, _ = np.linalg.qr(rng.standard_normal((d, zdim)))
    x = z @ q.T + 0.05 * rng.standard_normal((n, d))       # high-d noise floor
    return (x / np.abs(x).max()).astype(np.float32)


def scrna_like(n: int, seed: int = 0, d: int = 1000, modes: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base_rate = rng.gamma(0.3, 1.0, size=(modes, d))
    z = rng.integers(0, modes, size=n)
    lam = base_rate[z] * rng.gamma(2.0, 0.5, size=(n, 1))
    counts = rng.poisson(lam).astype(np.float32)
    mask = rng.uniform(size=(n, d)) < 0.85          # zero inflation (dropout)
    counts[mask] = 0.0
    return np.log1p(counts).astype(np.float32)


def scrna_pca_like(n: int, seed: int = 0, d: int = 10) -> np.ndarray:
    """The Appendix 1.3 violation regime: the bulk of the arm means is
    concentrated about the minimum (isotropic low-d Gaussian — shell
    concentration) while a few heavy-tailed outliers inflate every arm's
    reward tails (large sigma_x).  Scaling degrades to ~n^1.2 here."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    out = rng.uniform(size=n) < 0.03
    t = np.abs(rng.standard_t(2.0, size=(int(out.sum()), 1))).astype(np.float32)
    x[out] *= 1.0 + 3.0 * t
    return x


def hoc4_like(n: int, seed: int = 0, d: int = 32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    depth = rng.integers(1, 6, size=n)
    x = rng.poisson(lam=depth[:, None] * rng.uniform(0.2, 1.0, size=(1, d)))
    return x.astype(np.float32)


GENERATORS = {
    "mnist_like": mnist_like,
    "scrna_like": scrna_like,
    "scrna_pca_like": scrna_pca_like,
    "hoc4_like": hoc4_like,
}


def make(name: str, n: int, seed: int = 0, **kw) -> np.ndarray:
    return GENERATORS[name](n, seed=seed, **kw)
