"""The paper's comparison baselines (Fig. 1a): CLARANS, Voronoi Iteration,
CLARA.  These trade clustering quality for speed — the paper uses them to
show BanditPAM matches PAM's (better) loss.

Also FasterPAM (Schubert & Rousseeuw 2019/2021): the eager-swap exact
k-medoids reference.  Unlike PAM's best-swap-per-pass, it performs every
improving swap the moment it is found while sweeping the candidates, using
the same ``base + 1[y∈C_m]·corr`` decomposition as our fused SWAP step to
score all k removals of one candidate from a single distance row.  It
converges to a (possibly different) 1-swap local optimum of the same
neighbourhood structure as PAM, so it serves as the loss-parity check for
the BanditPAM++ reuse engine.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .banditpam import _swap_terms, medoid_cache, total_loss
from .distances import get_metric
from .pam import pam
from .report import FitReport

# Alias of the unified report type (see repro.core.report).
BaselineResult = FitReport


# ---------------------------------------------------------------------------
# FasterPAM (Schubert & Rousseeuw 2019) — eager multi-medoid swaps
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _eager_swap_delta(data, x, d1, d2, assign, *, metric: str, k: int):
    """Loss change of swapping candidate x in for each of the k medoids.

    One distance row d(x, ·) scores all k removals via the FastPAM1
    decomposition (the same base/corr split as the fused SWAP kernel):

        Δ(m) = Σ_y base_x(y) + Σ_{y∈C_m} corr_x(y)

    Returns (best slot, its Δ).
    """
    dx = get_metric(metric)(data[x][None, :], data)             # [1, n]
    base, corr = _swap_terms(dx, d1, d2)
    delta = jnp.sum(base) + jax.ops.segment_sum(corr[0], assign,
                                                num_segments=k)
    m = jnp.argmin(delta).astype(jnp.int32)
    return m, delta[m]


def fasterpam(data, k: int, metric: str = "l2", max_steps: Optional[int] = None,
              seed: int = 0, init=None) -> BaselineResult:
    """Eager-swap exact k-medoids: perform each improving swap immediately
    while sweeping candidates; stop after a full improvement-free sweep.

    Converges to a 1-swap local optimum of the same swap neighbourhood as
    PAM (typically matching its loss to within a percent from random init),
    at ``n`` distance evaluations per candidate scored plus an ``n·k``
    cache rebuild per accepted swap — the loss-parity reference for
    ``BanditPAM(reuse="pic")``.

    ``init`` seeds the medoids (e.g. with a BUILD result); default is a
    uniform random draw.
    """
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    if init is None:
        rng = np.random.default_rng(seed)
        medoids = jnp.asarray(rng.choice(n, size=k, replace=False).astype(np.int32))
    else:
        medoids = jnp.asarray(np.asarray(init, np.int32))
    d1, d2, assign = medoid_cache(data, medoids, metric=metric)
    evals = n * k
    loss = float(jnp.sum(d1))
    max_steps = max_steps if max_steps is not None else 50 * n
    med_set = set(np.asarray(medoids).tolist())
    since_improved, steps, x, n_swaps = 0, 0, 0, 0
    while since_improved < n and steps < max_steps:
        if x not in med_set:
            m_idx, dval = _eager_swap_delta(data, x, d1, d2, assign,
                                            metric=metric, k=k)
            evals += n
            if float(dval) < -1e-7 * max(1.0, abs(loss)):
                old = int(medoids[int(m_idx)])
                med_set.discard(old)
                med_set.add(x)
                medoids = medoids.at[int(m_idx)].set(x)
                d1, d2, assign = medoid_cache(data, medoids, metric=metric)
                evals += n * k
                loss = float(jnp.sum(d1))
                since_improved = 0
                n_swaps += 1
            else:
                since_improved += 1
        else:
            since_improved += 1
        x = (x + 1) % n
        steps += 1
    return BaselineResult(medoids=np.asarray(medoids), loss=loss,
                          distance_evals=evals, n_swaps=n_swaps,
                          converged=since_improved >= n,
                          evals_by_phase={"swap": evals})


# ---------------------------------------------------------------------------
# Voronoi Iteration (Park & Jun 2009) — k-means-style alternation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _voronoi_update(data, medoids, *, metric: str, k: int):
    """Reassign points, then recompute each cluster's medoid exactly.

    An empty cluster (possible when two medoids coincide or tie for all
    points — argmin assigns everything to the lower index) keeps its
    previous medoid: its cost column is all-inf, and electing argmin's
    arbitrary index 0 there would silently produce duplicate medoids.
    """
    n = data.shape[0]
    dist = get_metric(metric)
    dmat = dist(data, data[medoids])                    # [n, k]
    assign = jnp.argmin(dmat, axis=1)

    # Cost of x as medoid of cluster c: sum over members of d(x, y).
    # One [n, n] pass, masked per cluster via one-hot matmul.
    d_all = dist(data, data)                            # [n, n]
    onehot = jax.nn.one_hot(assign, k, dtype=d_all.dtype)   # [n, k]
    cost = d_all @ onehot                               # [n, k] Σ_{y∈C_c} d(x,y)
    member = onehot > 0
    cost = jnp.where(member, cost, jnp.inf)             # only members eligible
    nonempty = jnp.any(member, axis=0)                  # [k]
    new_medoids = jnp.where(nonempty,
                            jnp.argmin(cost, axis=0).astype(jnp.int32),
                            medoids.astype(jnp.int32))
    return new_medoids, assign


def voronoi_iteration(data, k: int, metric: str = "l2", max_iters: int = 50,
                      seed: int = 0) -> BaselineResult:
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    rng = np.random.default_rng(seed)
    medoids = jnp.asarray(rng.choice(n, size=k, replace=False).astype(np.int32))
    evals = 0
    converged = False
    for _ in range(max_iters):
        new_medoids, _ = _voronoi_update(data, medoids, metric=metric, k=k)
        evals += n * n + n * k
        if bool(jnp.all(new_medoids == medoids)):
            converged = True
            break
        medoids = new_medoids
    loss = float(total_loss(data, medoids, metric=metric))
    return BaselineResult(medoids=np.asarray(medoids), loss=loss,
                          distance_evals=evals, converged=converged,
                          evals_by_phase={"alternate": evals})


# ---------------------------------------------------------------------------
# CLARANS (Ng & Han 2002) — randomized swap-graph search
# ---------------------------------------------------------------------------

def clarans(data, k: int, metric: str = "l2", num_local: int = 2,
            max_neighbors: Optional[int] = None, seed: int = 0) -> BaselineResult:
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    if max_neighbors is None:
        max_neighbors = max(250, int(0.0125 * k * (n - k)))
    rng = np.random.default_rng(seed)
    best_loss, best_medoids = np.inf, None
    evals = 0
    for _ in range(num_local):
        medoids = rng.choice(n, size=k, replace=False).astype(np.int32)
        cur = jnp.asarray(medoids)
        cur_loss = float(total_loss(data, cur, metric=metric))
        evals += n * k
        # Host-side medoid set, maintained across accepted swaps; the
        # neighbour draw maps a uniform draw over the n-k non-medoids
        # through the sorted medoid list (order-statistic shift), so no
        # rejection loop is needed.  (Historically the draw rejected and
        # redrew whenever it hit a medoid — unbounded for small n-k —
        # and re-materialised the medoid array on every attempt.)
        cur_sorted = np.sort(np.asarray(cur))
        j = 0
        while j < max_neighbors:
            m_idx = int(rng.integers(k))
            x = int(rng.integers(n - k))
            for mval in cur_sorted:
                if x >= mval:
                    x += 1
            cand = cur.at[m_idx].set(x)
            cand_loss = float(total_loss(data, cand, metric=metric))
            evals += n * k
            if cand_loss < cur_loss:
                cur, cur_loss, j = cand, cand_loss, 0
                cur_sorted = np.sort(np.asarray(cur))
            else:
                j += 1
        if cur_loss < best_loss:
            best_loss, best_medoids = cur_loss, np.asarray(cur)
    return BaselineResult(medoids=best_medoids, loss=best_loss,
                          distance_evals=evals,
                          evals_by_phase={"search": evals})


# ---------------------------------------------------------------------------
# CLARA (Kaufman & Rousseeuw 1990) — PAM on subsamples
# ---------------------------------------------------------------------------

def clara(data, k: int, metric: str = "l2", n_samples: int = 5,
          sample_size: Optional[int] = None, seed: int = 0) -> BaselineResult:
    data_np = np.asarray(data, np.float32)
    n = data_np.shape[0]
    if sample_size is None:
        sample_size = min(n, 40 + 2 * k)
    rng = np.random.default_rng(seed)
    data_j = jnp.asarray(data_np)
    best_loss, best_medoids = np.inf, None
    evals = 0
    for _ in range(n_samples):
        sub_idx = rng.choice(n, size=sample_size, replace=False)
        sub_res = pam(data_np[sub_idx], k, metric=metric)
        evals += sub_res.distance_evals
        medoids_global = sub_idx[sub_res.medoids]
        loss = float(total_loss(data_j, jnp.asarray(medoids_global.astype(np.int32)),
                                metric=metric))
        evals += n * k
        if loss < best_loss:
            best_loss, best_medoids = loss, medoids_global
    return BaselineResult(medoids=np.asarray(best_medoids), loss=best_loss,
                          distance_evals=evals,
                          evals_by_phase={"subsample": evals})
