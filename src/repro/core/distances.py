"""Distance registry for k-medoids.

All functions compute *pairwise* dissimilarities between a target block
``x: [m, d]`` and a reference block ``y: [r, d]`` and return ``[m, r]``.

The k-medoids problem (paper Eq. 1/3) places no requirements on ``d`` —
it need not be symmetric, positive, or satisfy the triangle inequality —
so the registry is open: ``register_metric`` accepts any ``[m,d]x[r,d]->[m,r]``
callable.

The MXU-friendly metrics (``l2``, ``l2sq``, ``cosine``) are expressed as a
single matmul plus rank-1 corrections so both the jnp path (here) and the
Pallas path (``repro.kernels``) hit the systolic array.  ``l1`` is
bandwidth-bound and is evaluated in reference-chunks to bound the
``[m, chunk, d]`` intermediate.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Metric = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

_REGISTRY: Dict[str, Metric] = {}

# Keep the [m, chunk, d] L1 intermediate under ~2**24 elements.
_L1_CHUNK_ELEMS = 1 << 24


def register_metric(name: str, fn: Metric) -> None:
    _REGISTRY[name] = fn


def get_metric(name: str) -> Metric:
    if name not in _REGISTRY:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_metrics():
    return sorted(_REGISTRY)


def l2sq(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance via ||x||^2 + ||y||^2 - 2 x.y (MXU-shaped)."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(l2sq(x, y))


def cosine(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Cosine *distance* 1 - cos_sim, safe at zero vectors."""
    xn = x * jax.lax.rsqrt(jnp.maximum(jnp.sum(x * x, axis=-1, keepdims=True), 1e-30))
    yn = y * jax.lax.rsqrt(jnp.maximum(jnp.sum(y * y, axis=-1, keepdims=True), 1e-30))
    return 1.0 - xn @ yn.T


def l1(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Manhattan distance, chunked over references to bound memory."""
    m, d = x.shape
    r = y.shape[0]
    chunk = max(1, min(r, _L1_CHUNK_ELEMS // max(1, m * d)))
    if chunk >= r:
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)

    n_chunks = -(-r // chunk)
    pad = n_chunks * chunk - r
    y_pad = jnp.pad(y, ((0, pad), (0, 0)))
    y_chunks = y_pad.reshape(n_chunks, chunk, d)

    def one(yc):
        return jnp.sum(jnp.abs(x[:, None, :] - yc[None, :, :]), axis=-1)

    out = jax.lax.map(one, y_chunks)            # [n_chunks, m, chunk]
    out = jnp.moveaxis(out, 0, 1).reshape(m, n_chunks * chunk)
    return out[:, :r]


register_metric("l2", l2)
register_metric("l2sq", l2sq)
register_metric("l1", l1)
register_metric("cosine", cosine)


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise(x: jnp.ndarray, y: jnp.ndarray, *, metric: str = "l2") -> jnp.ndarray:
    """Jitted pairwise dissimilarity ``[m, d] x [r, d] -> [m, r]``."""
    return get_metric(metric)(x, y)
