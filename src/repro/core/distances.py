"""Distance registry for k-medoids.

All functions compute *pairwise* dissimilarities between a target block
``x: [m, d]`` and a reference block ``y: [r, d]`` and return ``[m, r]``.

The k-medoids problem (paper Eq. 1/3) places no requirements on ``d`` —
it need not be symmetric, positive, or satisfy the triangle inequality —
so the registry is open: ``register_metric`` accepts any ``[m,d]x[r,d]->[m,r]``
callable, and ``resolve_metric`` (what the ``repro.api`` facade calls)
additionally accepts a raw callable (auto-registered under a derived name)
or the string ``"precomputed"``.

``"precomputed"`` serves a caller-supplied ``[n, n]`` dissimilarity matrix
— the Eq. 1/3 formulation explicitly permits arbitrary dissimilarities, so
structured objects (the paper's code-submission trees under tree-edit
distance, say) cluster through the exact same solver stack.  Every solver
here only ever touches data through row indexing and ``get_metric``
blocks, so a matrix lookup can impersonate a metric: ``attach_index``
appends each row's own index as one extra feature column, and the
registered ``"precomputed"`` metric recovers ``D[I, J]`` for a block pair
by slicing the x-rows (which carry full D rows) at the y-rows' index
column.  Zero distance recomputation, identical solver code paths.

The MXU-friendly metrics (``l2``, ``l2sq``, ``cosine``) are expressed as a
single matmul plus rank-1 corrections so both the jnp path (here) and the
Pallas path (``repro.kernels``) hit the systolic array.  ``l1`` is
bandwidth-bound and is evaluated in reference-chunks to bound the
``[m, chunk, d]`` intermediate.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

Metric = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

_REGISTRY: Dict[str, Metric] = {}

# Keep the [m, chunk, d] L1 intermediate under ~2**24 elements.
_L1_CHUNK_ELEMS = 1 << 24


def register_metric(name: str, fn: Metric) -> None:
    _REGISTRY[name] = fn


def get_metric(name: str) -> Metric:
    if name not in _REGISTRY:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_metrics():
    return sorted(_REGISTRY)


def l2sq(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distance via ||x||^2 + ||y||^2 - 2 x.y (MXU-shaped)."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(l2sq(x, y))


def cosine(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Cosine *distance* 1 - cos_sim, safe at zero vectors."""
    xn = x * jax.lax.rsqrt(jnp.maximum(jnp.sum(x * x, axis=-1, keepdims=True), 1e-30))
    yn = y * jax.lax.rsqrt(jnp.maximum(jnp.sum(y * y, axis=-1, keepdims=True), 1e-30))
    return 1.0 - xn @ yn.T


def l1(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Manhattan distance, chunked over references to bound memory."""
    m, d = x.shape
    r = y.shape[0]
    chunk = max(1, min(r, _L1_CHUNK_ELEMS // max(1, m * d)))
    if chunk >= r:
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)

    n_chunks = -(-r // chunk)
    pad = n_chunks * chunk - r
    y_pad = jnp.pad(y, ((0, pad), (0, 0)))
    y_chunks = y_pad.reshape(n_chunks, chunk, d)

    def one(yc):
        return jnp.sum(jnp.abs(x[:, None, :] - yc[None, :, :]), axis=-1)

    out = jax.lax.map(one, y_chunks)            # [n_chunks, m, chunk]
    out = jnp.moveaxis(out, 0, 1).reshape(m, n_chunks * chunk)
    return out[:, :r]


# ---------------------------------------------------------------------------
# Precomputed dissimilarities
# ---------------------------------------------------------------------------

# f32 holds integers exactly up to 2**24, which bounds the index column.
_MAX_PRECOMPUTED_N = 1 << 24


def attach_index(dissim) -> jnp.ndarray:
    """Prepare an ``[n, n]`` dissimilarity matrix for ``metric="precomputed"``:
    append each row's own index as a trailing feature column, so row blocks
    stay self-describing under the index-only data access of the solvers."""
    d = jnp.asarray(dissim, jnp.float32)
    if d.ndim != 2 or d.shape[0] != d.shape[1]:
        raise ValueError(f'metric="precomputed" expects a square [n, n] '
                         f"dissimilarity matrix, got shape {d.shape}")
    n = d.shape[0]
    if n >= _MAX_PRECOMPUTED_N:
        raise ValueError(f"precomputed index column is exact only for "
                         f"n < {_MAX_PRECOMPUTED_N}, got n={n}")
    idx = jnp.arange(n, dtype=jnp.float32)[:, None]
    return jnp.concatenate([d, idx], axis=1)


def precomputed(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Lookup 'metric' over ``attach_index``-augmented data: x rows carry
    ``D[i, :]``, the y rows' trailing column carries ``j`` — the pairwise
    block is a pure gather ``D[I, J]``.

    On eager (non-traced) calls the index column is validated, so passing
    a raw, un-augmented matrix to a legacy entrypoint fails loudly at the
    first eager distance call instead of silently gathering garbage
    (inside jit the column is a tracer and the check is skipped — the
    facade routes everything through ``attach_index`` anyway)."""
    col = y[:, -1]
    if not isinstance(col, jax.core.Tracer):
        cv = np.asarray(col)
        if cv.size and (cv.min() < 0 or cv.max() > x.shape[1] - 2
                        or np.any(cv != np.round(cv))):
            raise ValueError(
                'metric="precomputed" data must be routed through '
                "attach_index() (the trailing column must hold row "
                "indices); got non-index values — pass the raw [n, n] "
                "matrix to repro.api.KMedoids, or call attach_index "
                "yourself before the legacy entrypoints")
    j = col.astype(jnp.int32)
    return jnp.take(x[:, :-1], j, axis=1)


def resolve_metric(metric) -> str:
    """Normalise a user-facing ``metric`` argument to a registered name.

    Accepts a registered name (validated), the string ``"precomputed"``
    (the caller is responsible for routing data through ``attach_index``),
    or a raw ``[m,d]x[r,d]->[m,r]`` callable — auto-registered under a name
    derived from the function (idempotent for the same object, so jit
    caches keyed on the name stay warm).

    Each DISTINCT callable gets its own registry entry for process
    lifetime: re-registering an existing name would silently serve stale
    jit traces keyed on that name.  Long-running processes that generate
    many throwaway lambdas should ``register_metric`` one stable name
    instead.
    """
    if isinstance(metric, str):
        get_metric(metric)  # raises KeyError for unknown names
        return metric
    if callable(metric):
        for name, fn in _REGISTRY.items():
            if fn is metric:
                return name
        base = getattr(metric, "__name__", None) or "metric"
        name, i = base, 0
        while name in _REGISTRY:   # never clobber an existing registration
            i += 1
            name = f"{base}_{i}"
        register_metric(name, metric)
        return name
    raise TypeError(f"metric must be a registered name, 'precomputed', or a "
                    f"callable; got {type(metric).__name__}")


register_metric("l2", l2)
register_metric("l2sq", l2sq)
register_metric("l1", l1)
register_metric("cosine", cosine)
register_metric("precomputed", precomputed)


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise(x: jnp.ndarray, y: jnp.ndarray, *, metric: str = "l2") -> jnp.ndarray:
    """Jitted pairwise dissimilarity ``[m, d] x [r, d] -> [m, r]``."""
    return get_metric(metric)(x, y)
