"""Distributed BanditPAM: data-sharded references x replicated/sharded arms.

The multi-device execution of Algorithm 1 (docs/design.md hardware
adaptations #2/#4, mesh conventions §2):

* The reference set is sharded over the ``data`` (and ``pod``) mesh axes —
  each device owns ``ceil(n / n_shards)`` points (the sharded view is
  padded to a shard multiple with cyclic copies; padding rows sit past
  each shard's valid-draw range so they are never sampled, shards are
  weighted by their valid-row count, and all-padding shards carry weight
  0 — padding never reaches the statistics or the loss).
* Reference sampling is **stratified**: every round each shard contributes
  ``B / n_shards`` uniform draws from its *valid* local points, weighted
  by its stratum size so the estimator of mu_x stays unbiased even when
  the strata are uneven (docs/design.md hardware adaptation #4).  Draws
  are keyed by ``(seed, phase, selection/iteration, round, shard)`` — the
  round counter is folded in explicitly, so no two rounds of a fit can
  ever see identical reference batches (Theorem 1's confidence intervals
  assume fresh, independent batches per round).
* Each device computes the g-statistics of ALL arms against its local
  reference draw **through the registered ``StatsBackend``**
  (``repro.core.engine``): one backend ``pairwise`` block plus the
  backend's from-distances statistics (for ``"pallas"`` that is the tiled
  MXU pairwise kernel and the fused cached-stats SWAP kernel).  A single
  ``psum`` over the data axes — the only collective, owned by this layer,
  never by a backend — yields the global per-arm batch sums.  Arm
  elimination runs redundantly on every device (cheap vector math, saves
  a broadcast).
* **The whole BUILD phase is ONE jit dispatch**: a ``lax.fori_loop`` over
  the k medoid selections with the ``shard_map``-ed bandit search inside
  and ``d_near`` / the medoid mask (and the sharded PIC cache) as loop
  carry — the historical one-dispatch-per-selection shape (k host syncs)
  is gone; ``benchmarks/distributed_bench.py`` asserts the single
  dispatch and records the saving.
* The SWAP loop follows the fused per-iteration step shape of the
  single-device driver (docs/design.md hardware adaptation #5): one jit
  dispatch per iteration (medoid-cache refresh + carried-moment repair +
  sharded bandit search + candidate loss); the host only reads the
  accept/converge scalar.
* ``reuse="pic"`` enables the BanditPAM++ reuse engine on the sharded
  path: reference sampling switches to a **stratified fixed permutation**
  (each shard walks a fixed random permutation of its own valid rows;
  round ``r`` is slice ``[r·b_loc, (r+1)·b_loc)`` of every shard's walk,
  so the schedule is deterministic and every point is consumed exactly
  once at full budget — stratum weights are a replacement-mode device
  and are not used), and the bounded PIC column ring
  (``repro.core.pic_cache``) is **sharded over the data axes by
  reference ownership**: each shard holds the ``[n, W·b_loc]`` block of
  the columns its own rows produce, read/written from inside
  ``shard_map`` exactly like the single-device ``adaptive_search`` aux
  threading.  Carried per-arm moments are repaired after each accepted
  swap by a per-shard delta pass over the sharded columns (one extra
  ``psum``), giving multi-swap sharded fits the same fresh/cached ledger
  split as the single-device engine.
* The hierarchical pod axis composes transparently: ``psum`` over
  ("pod", "data") is the cross-pod reduction.

``MedoidCurator`` is the LM-stack integration: it consumes embedding
shards (activations or dataset features) that already live sharded across
the data axis of a training/serving mesh and returns medoid indices +
assignments for data curation (examples/train_lm_curated.py).

The facade front-end is ``repro.api.KMedoids(solver="banditpam_dist",
mesh=..., backend=...)`` (``repro.api.registry``); without ``mesh=`` it
spans every local device (``default_mesh``).
"""

from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .adaptive import adaptive_search
from .engine import (counted_dispatch, exact_build_means, exact_swap_means,
                     get_stats_backend, medoid_cache, resolve_stats_backend,
                     total_loss)
from .pic_cache import (PicCache, cache_advance, carry_valid,
                        fresh_positions, resolve_cache_rounds,
                        shard_slot_read_write)
from .report import FitReport

__all__ = ["DistributedBanditPAM", "MedoidCurator", "default_mesh"]


if hasattr(jax, "shard_map"):                       # jax >= 0.6

    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    def _shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_mesh() -> Mesh:
    """One-axis ``("data",)`` mesh spanning every local device — the
    facade's default when ``KMedoids(solver="banditpam_dist")`` is given
    no ``mesh=``."""
    devs = np.asarray(jax.devices())
    return Mesh(devs.reshape(devs.size), ("data",))


# ---------------------------------------------------------------------------
# Sharded-sampler RNG chain
#
# Key schedule: PRNGKey(seed ^ phase_tag) -> fold(selection/iteration)
# -> fold(round) -> fold(shard).  Every level is folded in explicitly, so
# two distinct (phase, step, round, shard) tuples draw independent
# batches.  (Historically the chain keyed on the adaptive loop's
# ref_idx[0] and ignored the round counter entirely, so two rounds whose
# first sampled index collided silently reused identical reference
# batches — breaking the cross-round independence the Theorem 1
# confidence intervals assume.  tests/test_distributed_fit.py holds the
# regression.)
# ---------------------------------------------------------------------------

_BUILD_TAG = 0x5EED
_SWAP_TAG = 0x50A9


def _phase_key(seed: int, tag: int, step) -> jax.Array:
    """Base key of one bandit search: ``step`` is the BUILD selection
    index or the SWAP iteration counter."""
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ tag), step)


def _round_key(phase_key: jax.Array, rnd) -> jax.Array:
    """Per-round key: folds ``adaptive_search``'s round counter."""
    return jax.random.fold_in(phase_key, rnd)


def _shard_draws(round_key: jax.Array, ax, n_valid, b_loc: int) -> jnp.ndarray:
    """Shard ``ax``'s stratified draw: ``b_loc`` uniform indices into its
    valid local rows (``max(n_valid, 1)`` guards all-padding shards, whose
    stratum weight is 0 anyway)."""
    kk = jax.random.fold_in(round_key, ax)
    return jax.random.randint(kk, (b_loc,), 0, jnp.maximum(n_valid, 1))


# Compiled phase steps, shared across instances: jax.jit's cache is keyed
# on the function object, so rebuilding the step closures every fit would
# recompile both phases.  A module-level table (like the single-device
# driver's module-level jits) makes repeated fits retrace-free even when
# each fit constructs a fresh estimator — the facade registry does exactly
# that.  Keys cover everything the closures capture (see ``_step_key``).
_STEP_CACHE: dict = {}


class DistributedBanditPAM:
    """BanditPAM over a sharded reference set.

    data: [n, d] array (host); sharded internally over the mesh's data
    axes (padded to a shard multiple when n is uneven — padding rows are
    masked out of sampling, statistics, and loss).  Semantics match
    `BanditPAM` (same medoids as PAM w.h.p.); the sampling schedule
    differs (stratified per shard), so seeds are not comparable with the
    single-device class.

    ``backend`` selects the shard-local g-statistics path
    (``repro.core.engine``): ``"auto"`` | ``"pallas"`` | ``"jnp"`` or any
    registered stats backend.  The ``psum`` composition lives here; the
    backends stay collective-free.

    ``reuse="pic"`` enables the BanditPAM++ reuse engine (stratified
    fixed-permutation sampling + the mesh-sharded bounded PIC column
    ring; see the module docstring); ``cache_width`` caps the ring in
    global reference columns (default a few dozen round-batches,
    O(n·width/n_shards) memory per shard).
    """

    def __init__(self, k: int, mesh: Mesh, metric: str = "l2",
                 batch_size: int = 128, delta: Optional[float] = None,
                 max_swaps: Optional[int] = None, seed: int = 0,
                 backend: str = "auto", reuse: str = "none",
                 cache_width: Optional[int] = None):
        if reuse not in ("none", "pic"):
            raise ValueError(f"unknown reuse mode {reuse!r}")
        self.k = int(k)
        self.mesh = mesh
        self.metric = metric
        self.daxes = _data_axes(mesh)
        if not self.daxes:
            raise ValueError(f"mesh has no data axes; axis names must "
                             f"include 'data' and/or 'pod', got "
                             f"{mesh.axis_names}")
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.daxes]))
        if batch_size % self.n_shards:
            batch_size += self.n_shards - batch_size % self.n_shards
        self.batch_size = batch_size
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed
        self.backend = backend
        self.reuse = reuse
        self.cache_width = cache_width

    def _step_key(self, phase: str, backend: str, n: int, delta: float,
                  cache_rounds: int = 0):
        """Cache key covering everything the compiled phase closures
        capture: mesh (axes, shard count), backend, shapes, metric, the
        static batch/confidence parameters, and the cache regime."""
        return (phase, self.mesh, backend, n, self.k, self.metric,
                self.batch_size, delta, self.reuse, cache_rounds)

    # -- sharded stats ----------------------------------------------------
    def _shard_data(self, data: jnp.ndarray) -> jnp.ndarray:
        """The sharded reference view: rows padded to a shard multiple
        with cyclic copies (real points, so every metric stays NaN-free;
        the stratum weights below zero them out of the statistics).  The
        modular gather also covers n smaller than the mesh, where the
        padding wraps around the data more than once."""
        n = data.shape[0]
        n_pad = self._n_loc(n) * self.n_shards
        if n_pad != n:
            data = data[jnp.arange(n_pad) % n]
        return jax.device_put(
            data, NamedSharding(self.mesh, P(self.daxes, None)))

    def _n_loc(self, n: int) -> int:
        return -(-n // self.n_shards)

    def _flat_ax(self):
        """The shard's flattened index over the (pod, data) strata."""
        daxes = self.daxes
        if len(daxes) == 1:
            return lambda: jax.lax.axis_index(daxes[0])
        m2 = self.mesh.shape[daxes[1]]
        return lambda: (jax.lax.axis_index(daxes[0]) * m2
                        + jax.lax.axis_index(daxes[1]))

    def _stratum(self, n: int, n_loc: int, ax):
        """(valid row count, stratum weight) of shard ``ax``.

        The weight ``v·n_shards/n`` makes the equal-draws-per-shard
        estimator unbiased under uneven strata: each draw of shard s
        estimates mean_s, and sum_s (B/n_shards)·w_s·mean_s / B =
        sum_s (v_s/n)·mean_s — the global mean.  Even split ⇒ w ≡ 1."""
        v = jnp.clip(n - ax * n_loc, 0, n_loc)
        return v, v.astype(jnp.float32) * self.n_shards / n

    def _build_smap(self, be, n: int):
        """Sharded BUILD statistics: ``smap(data_f, data_l, dnear_f,
        round_key, lead) -> (sums, sqsums, cross)``, psum'd over the data
        axes.  The shard-local stats go through the stats backend; only
        the reduction is owned here."""
        metric = self.metric
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()

        def local(data_f, data_l, dnear_f, rkey, lead):
            ax = axfn()
            v, cs = self._stratum(n, n_loc, ax)
            idx = _shard_draws(rkey, ax, v, b_loc)
            gidx = jnp.minimum(ax * n_loc + idx, n - 1)
            w = jnp.ones((b_loc,), jnp.float32)
            dxy = be.pairwise(data_f, data_l[idx], metric=metric)  # [n, b_loc]
            s, q, c = be.build_stats_from_d(dxy, dnear_f[gidx], w, lead)
            return (jax.lax.psum(s * cs, self.daxes),
                    jax.lax.psum(q * (cs * cs), self.daxes),
                    jax.lax.psum(c * (cs * cs), self.daxes))

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(), P(), P()),
                          out_specs=(P(), P(), P()))

    def _swap_smap(self, be, n: int):
        """Sharded SWAP statistics over the flattened (medoid, candidate)
        arm set: ``smap(data_f, data_l, d1_f, d2_f, assign_f, round_key,
        lead)``.  On the Pallas backend the from-distances stats hit the
        fused cached-stats kernel."""
        metric = self.metric
        k = self.k
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()

        def local(data_f, data_l, d1_f, d2_f, a_f, rkey, lead):
            ax = axfn()
            v, cs = self._stratum(n, n_loc, ax)
            idx = _shard_draws(rkey, ax, v, b_loc)
            gidx = jnp.minimum(ax * n_loc + idx, n - 1)
            w = jnp.ones((b_loc,), jnp.float32)
            dxy = be.pairwise(data_f, data_l[idx], metric=metric)
            s, q, c = be.swap_stats_from_d(dxy, d1_f[gidx], d2_f[gidx],
                                           a_f[gidx], w, k, lead)
            return (jax.lax.psum(s * cs, self.daxes),
                    jax.lax.psum(q * (cs * cs), self.daxes),
                    jax.lax.psum(c * (cs * cs), self.daxes))

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(), P(), P(),
                                    P(), P()),
                          out_specs=(P(), P(), P()))

    # -- PIC: stratified permutation layout + sharded column ring ---------
    def _pic_layout(self, n: int, ckey: jax.Array):
        """Build the ``reuse="pic"`` sampling schedule and cache buffers.

        Each shard gets a fixed random permutation of its ``n_loc`` local
        rows; round ``r`` is slice ``[r·b_loc, (r+1)·b_loc)`` of every
        shard's walk.  Positions whose value falls outside the shard's
        valid rows (cyclic padding) carry weight 0, so every real point
        is consumed exactly once across the ``R_max`` rounds — at full
        budget the running mean IS the exact mean, like the single-device
        permutation mode (stratum weights are a replacement-mode device
        and are not used here).

        Returns ``(lperm, lw, perm_idx_g, perm_w_g, cache, W)``: the
        per-shard walks ``[S, R_max·b_loc]`` (sharded over the data
        axes), the matching global position layout ``[R_max·B]`` for
        ``adaptive_search``'s budget accounting, the all-cold sharded
        column ring (cols ``[n, S·W·b_loc]`` sharded by reference
        ownership), and the ring capacity in rounds.
        """
        S = self.n_shards
        b_loc = self.batch_size // S
        n_loc = self._n_loc(n)
        r_max = -(-n_loc // b_loc)
        W = resolve_cache_rounds(r_max, self.batch_size, self.cache_width)
        width_loc = r_max * b_loc
        lperm = np.empty((S, width_loc), np.int32)
        lw = np.empty((S, width_loc), np.float32)
        pos = np.arange(width_loc)
        for s in range(S):
            p = np.asarray(jax.random.permutation(
                jax.random.fold_in(ckey, s), n_loc), np.int32)
            tiled = np.tile(p, -(-width_loc // n_loc))[:width_loc]
            v = min(max(n - s * n_loc, 0), n_loc)
            lperm[s] = tiled
            lw[s] = ((pos < n_loc) & (tiled < v)).astype(np.float32)
        gidx = np.minimum(np.arange(S)[:, None] * n_loc + lperm, n - 1)
        # Global layout: round r occupies slots [r·B, (r+1)·B), shard s
        # owning the [s·b_loc, (s+1)·b_loc) sub-slice — the exact order
        # the shard-local draws are concatenated in.
        to_global = lambda a: jnp.asarray(
            a.reshape(S, r_max, b_loc).transpose(1, 0, 2).reshape(-1))
        sh_rows = NamedSharding(self.mesh, P(self.daxes, None))
        sh_cols = NamedSharding(self.mesh, P(None, self.daxes))
        lperm_d = jax.device_put(jnp.asarray(lperm), sh_rows)
        lw_d = jax.device_put(jnp.asarray(lw), sh_rows)
        cache = PicCache(
            cols=jax.device_put(
                jnp.zeros((n, S * W * b_loc), jnp.float32), sh_cols),
            hw=jnp.int32(0), fresh_pos=jnp.uint32(0))
        return (lperm_d, lw_d, to_global(gidx.astype(np.int32)),
                to_global(lw), cache, W)

    def _build_smap_pic(self, be, n: int, W: int):
        """Sharded BUILD statistics under the stratified fixed
        permutation, served through the shard-local PIC column ring:
        ``smap(data_f, data_l, dnear_f, lperm, lw, cols, rnd, hw, lead)
        -> (sums, sqsums, cross, cols')``."""
        metric = self.metric
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()
        daxes = self.daxes

        def local(data_f, data_l, dnear_f, lperm, lw, cols, rnd, hw, lead):
            ax = axfn()
            lidx = jax.lax.dynamic_slice(lperm[0], (rnd * b_loc,), (b_loc,))
            w = jax.lax.dynamic_slice(lw[0], (rnd * b_loc,), (b_loc,))
            gidx = jnp.minimum(ax * n_loc + lidx, n - 1)
            dxy, cols = shard_slot_read_write(
                cols, rnd, hw, b_loc,
                lambda: be.pairwise(data_f, data_l[lidx], metric=metric))
            s, q, c = be.build_stats_from_d(dxy, dnear_f[gidx], w, lead)
            return (jax.lax.psum(s, daxes), jax.lax.psum(q, daxes),
                    jax.lax.psum(c, daxes), cols)

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(),
                                    P(self.daxes, None), P(self.daxes, None),
                                    P(None, self.daxes), P(), P(), P()),
                          out_specs=(P(), P(), P(), P(None, self.daxes)))

    def _swap_smap_pic(self, be, n: int, W: int):
        """Sharded SWAP statistics under the stratified fixed permutation
        + shard-local PIC ring (FastPAM1 flattened arm set)."""
        metric = self.metric
        k = self.k
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()
        daxes = self.daxes

        def local(data_f, data_l, d1_f, d2_f, a_f, lperm, lw, cols, rnd, hw,
                  lead):
            ax = axfn()
            lidx = jax.lax.dynamic_slice(lperm[0], (rnd * b_loc,), (b_loc,))
            w = jax.lax.dynamic_slice(lw[0], (rnd * b_loc,), (b_loc,))
            gidx = jnp.minimum(ax * n_loc + lidx, n - 1)
            dxy, cols = shard_slot_read_write(
                cols, rnd, hw, b_loc,
                lambda: be.pairwise(data_f, data_l[lidx], metric=metric))
            s, q, c = be.swap_stats_from_d(dxy, d1_f[gidx], d2_f[gidx],
                                           a_f[gidx], w, k, lead)
            return (jax.lax.psum(s, daxes), jax.lax.psum(q, daxes),
                    jax.lax.psum(c, daxes), cols)

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(), P(), P(),
                                    P(self.daxes, None), P(self.daxes, None),
                                    P(None, self.daxes), P(), P(), P()),
                          out_specs=(P(), P(), P(), P(None, self.daxes)))

    def _carry_smap(self, be, n: int, W: int):
        """Carried-moment repair over the sharded PIC columns: each shard
        re-scores only its own changed prefix positions (old vs new
        medoid cache) and one ``psum`` composes the global per-arm delta
        — zero fresh distance evaluations, exactly the single-device
        ``banditpam._carry_delta`` split over reference ownership."""
        k = self.k
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        width_loc = W * b_loc
        axfn = self._flat_ax()
        daxes = self.daxes

        def local(cols, lperm, lw, n_prefix_loc, d1o, d2o, ao, d1n, d2n, an):
            ax = axfn()
            pidx = lperm[0][:width_loc]
            pw = lw[0][:width_loc]
            gidx = jnp.minimum(ax * n_loc + pidx, n - 1)
            in_prefix = (jnp.arange(width_loc) < n_prefix_loc).astype(
                jnp.float32)
            b1, b2, ba = d1o[gidx], d2o[gidx], ao[gidx]
            c1, c2, ca = d1n[gidx], d2n[gidx], an[gidx]
            changed = ((b1 != c1) | (b2 != c2) | (ba != ca)).astype(
                jnp.float32)
            w = pw * in_prefix * changed
            s_old, q_old, _ = be.swap_stats_from_d(cols, b1, b2, ba, w, k,
                                                   None)
            s_new, q_new, _ = be.swap_stats_from_d(cols, c1, c2, ca, w, k,
                                                   None)
            return (jax.lax.psum(s_new - s_old, daxes),
                    jax.lax.psum(q_new - q_old, daxes),
                    jax.lax.psum(jnp.sum(w), daxes))

        return _shard_map(local, self.mesh,
                          in_specs=(P(None, self.daxes),
                                    P(self.daxes, None), P(self.daxes, None),
                                    P(), P(), P(), P(), P(), P(), P()),
                          out_specs=(P(), P(), P()))

    # -- fused phase steps -----------------------------------------------
    def _make_build_phase(self, be, n: int, delta: float, W: int):
        """The whole BUILD phase as ONE jit dispatch: ``fori_loop`` over
        the k medoid selections with the ``shard_map``-ed bandit search
        inside and d_near / the medoid mask / the sharded PIC ring as
        loop carry — the single-device ``_build_fused`` shape with the
        shard_map inside the loop.  The host reads only the final
        medoids and ledger arrays.  ``data``/``data_sh`` are jit
        arguments (not closure constants) so XLA never constant-folds
        distance blocks at compile time."""
        mode = self.reuse
        smap = (self._build_smap_pic(be, n, W) if mode == "pic"
                else self._build_smap(be, n))
        metric = self.metric
        B = self.batch_size
        k = self.k

        @jax.jit
        def build_phase(data, data_sh, base_key, subkeys, lperm, lw,
                        perm_idx_g, perm_w_g, cache):
            def body(i, c):
                dnear, med_mask, medoids, cc, rounds_a, evals_a, cached_a = c
                if mode == "pic":
                    def stats_fn(ref_idx, w, lead, rnd, aux):
                        s, q, cr, cols = smap(data, data_sh, dnear, lperm,
                                              lw, aux.cols, rnd, aux.hw,
                                              lead)
                        return s, q, cr, cache_advance(
                            aux, cols, rnd, jnp.sum(w), W)

                    sr = adaptive_search(
                        subkeys[i], stats_fn=stats_fn,
                        exact_fn=lambda: exact_build_means(
                            be, data, dnear, metric=metric),
                        n_arms=n, n_ref=n, batch_size=B, delta=delta,
                        active_init=jnp.logical_not(med_mask),
                        sampling="permutation", baseline="leader",
                        perm_idx=perm_idx_g, perm_w=perm_w_g,
                        free_rounds=cc.hw,
                        free_lo=jnp.maximum(cc.hw - W, 0), aux_init=cc)
                else:
                    phase_key = jax.random.fold_in(base_key, i)

                    def stats_fn(ref_idx, w, lead, rnd):
                        # The adaptive loop's own (replacement-mode) draw
                        # is ignored; each shard draws locally from the
                        # round key.
                        return smap(data, data_sh, dnear,
                                    _round_key(phase_key, rnd), lead)

                    sr = adaptive_search(
                        subkeys[i], stats_fn=stats_fn,
                        exact_fn=lambda: exact_build_means(
                            be, data, dnear, metric=metric),
                        n_arms=n, n_ref=n, batch_size=B, delta=delta,
                        active_init=jnp.logical_not(med_mask),
                        sampling="replacement", baseline="leader")
                m = sr.best
                medoids = medoids.at[i].set(m)
                med_mask = med_mask.at[m].set(True)
                dnear = jnp.minimum(
                    dnear,
                    be.pairwise(data[m][None, :], data, metric=metric)[0])
                if mode == "pic":
                    # Fresh POSITION count; the host multiplies by n
                    # (a device uint32 n·Δ product would wrap at large n).
                    cc2 = sr.aux
                    fresh = fresh_positions(cc, cc2)
                    cached_a = cached_a.at[i].set(sr.n_evals_cached)
                    cc = cc2
                else:
                    fresh = sr.n_evals
                evals_a = evals_a.at[i].set(fresh)
                rounds_a = rounds_a.at[i].set(sr.rounds)
                return (dnear, med_mask, medoids, cc, rounds_a, evals_a,
                        cached_a)

            init = (jnp.full((n,), jnp.inf, jnp.float32),
                    jnp.zeros((n,), jnp.bool_),
                    jnp.zeros((k,), jnp.int32),
                    cache,
                    jnp.zeros((k,), jnp.int32),
                    jnp.zeros((k,), jnp.uint32),
                    jnp.zeros((k,), jnp.uint32))
            return jax.lax.fori_loop(0, k, body, init)

        return build_phase

    def _make_swap_iter(self, be, n: int, delta: float, W: int):
        """One SWAP iteration as ONE fused jit dispatch (hardware
        adaptation #5 shape): medoid-cache refresh (+ carried-moment
        repair over the sharded PIC columns under ``reuse="pic"``) +
        sharded bandit search + candidate loss; only the accept/converge
        scalar is read on host."""
        mode = self.reuse
        smap = (self._swap_smap_pic(be, n, W) if mode == "pic"
                else self._swap_smap(be, n))
        carry_smap = self._carry_smap(be, n, W) if mode == "pic" else None
        metric = self.metric
        B = self.batch_size
        b_loc = B // self.n_shards
        k = self.k

        @jax.jit
        def swap_iter(data, data_sh, medoids, med_mask, phase_key,
                      search_key, lperm, lw, perm_idx_g, perm_w_g, cache,
                      carry):
            d1, d2, assign = medoid_cache(data, medoids, metric=metric)
            n_changed = jnp.int32(0)
            init_sums = init_sqsums = None
            init_rounds = 0
            if mode == "pic" and carry is not None:
                # Repair the carried per-arm moments against the new
                # medoid cache from the sharded PIC columns (zero fresh
                # evals); once ring recycling has evicted part of the
                # carried prefix the repair is skipped entirely
                # (lax.cond) and the search starts cold.
                c_sums, c_sq, c_rounds, d1o, d2o, ao = carry
                valid = carry_valid(cache, rounds_cap=W)

                def repair(_):
                    ds, dq, nch = carry_smap(
                        cache.cols, lperm, lw, c_rounds * b_loc,
                        d1o, d2o, ao, d1, d2, assign)
                    return c_sums + ds, c_sq + dq, nch.astype(jnp.int32)

                def cold(_):
                    return (jnp.zeros_like(c_sums), jnp.zeros_like(c_sq),
                            jnp.int32(0))

                init_sums, init_sqsums, n_changed = jax.lax.cond(
                    valid, repair, cold, None)
                init_rounds = jnp.where(valid, c_rounds, 0)

            active0 = jnp.tile(jnp.logical_not(med_mask)[None, :],
                               (k, 1)).reshape(-1)

            def count_fn(active):
                # FastPAM1: one distance per (x, y) serves all k arms (·, x).
                any_x = jnp.any(active.reshape(k, n), axis=0)
                return jnp.sum(any_x.astype(jnp.uint32))

            def exact_fn():
                return exact_swap_means(be, data, d1, d2, assign, k,
                                        metric=metric)

            if mode == "pic":
                def stats_fn(ref_idx, w, lead, rnd, aux):
                    s, q, cr, cols = smap(data, data_sh, d1, d2, assign,
                                          lperm, lw, aux.cols, rnd, aux.hw,
                                          lead)
                    return s, q, cr, cache_advance(
                        aux, cols, rnd, jnp.sum(w), W)

                sr = adaptive_search(
                    search_key, stats_fn=stats_fn, exact_fn=exact_fn,
                    n_arms=k * n, n_ref=n, batch_size=B, delta=delta,
                    active_init=active0, count_fn=count_fn,
                    sampling="permutation", baseline="leader",
                    perm_idx=perm_idx_g, perm_w=perm_w_g,
                    free_rounds=cache.hw,
                    free_lo=jnp.maximum(cache.hw - W, 0),
                    init_sums=init_sums, init_sqsums=init_sqsums,
                    init_rounds=init_rounds, aux_init=cache)
                cache2 = sr.aux
                fresh = fresh_positions(cache, cache2)
                cached = sr.n_evals_cached
            else:
                def stats_fn(ref_idx, w, lead, rnd):
                    return smap(data, data_sh, d1, d2, assign,
                                _round_key(phase_key, rnd), lead)

                sr = adaptive_search(
                    search_key, stats_fn=stats_fn, exact_fn=exact_fn,
                    n_arms=k * n, n_ref=n, batch_size=B, delta=delta,
                    active_init=active0, count_fn=count_fn,
                    sampling="replacement", baseline="leader")
                cache2 = cache
                fresh = sr.n_evals
                cached = sr.n_evals_cached
            m_idx = sr.best // n
            x_idx = sr.best % n
            cand = medoids.at[m_idx].set(x_idx)
            new_loss = total_loss(data, cand, metric=metric)
            new_carry = (sr.sums, sr.sqsums, sr.rounds, d1, d2, assign)
            # fresh is a POSITION count and n_changed a point count under
            # "pic"; the host multiplies both by n (uint32-safe).
            return (sr.best, new_loss, cand, new_carry, cache2, fresh,
                    cached, n_changed, sr.used_exact)

        return swap_iter

    # -- fit --------------------------------------------------------------
    def fit(self, data) -> FitReport:
        data = jnp.asarray(data, jnp.float32)
        n = data.shape[0]
        if n <= self.k:
            raise ValueError("need n > k")
        backend = resolve_stats_backend(self.backend, self.metric)
        be = get_stats_backend(backend)
        data_sh = self._shard_data(data)
        key = jax.random.PRNGKey(self.seed)
        res = FitReport(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0,
                        solver="banditpam_dist", metric=str(self.metric))

        pic = self.reuse == "pic"
        if pic:
            key, ckey = jax.random.split(key)
            lperm, lw, pidx_g, pw_g, cache, W = self._pic_layout(n, ckey)
        else:
            lperm = lw = pidx_g = pw_g = cache = None
            W = 0

        # BUILD — the whole phase is ONE jit dispatch (fori_loop over the
        # k selections, shard_map inside); the host reads only the final
        # medoids and ledger arrays.
        t0 = time.perf_counter()
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        ck = self._step_key("build", backend, n, delta, W)
        if ck not in _STEP_CACHE:
            _STEP_CACHE[ck] = self._make_build_phase(be, n, delta, W)
        # dispatches_by_phase is MEASURED at the call sites (one count per
        # compiled-phase call) — the bench assertion guards real behavior.
        build_phase = counted_dispatch(_STEP_CACHE[ck],
                                       res.dispatches_by_phase, "build")
        # One subkey per medoid selection, split exactly as the historical
        # per-selection host loop did, so trajectories are seed-compatible.
        subs = []
        for _ in range(self.k):
            key, sub = jax.random.split(key)
            subs.append(sub)
        (dnear, med_mask, med, cache, rounds_a, evals_a,
         cached_a) = build_phase(
            data, data_sh, jax.random.PRNGKey(self.seed ^ _BUILD_TAG),
            jnp.stack(subs), lperm, lw, pidx_g, pw_g, cache)
        res.build_rounds.extend(
            int(r) for r in np.asarray(rounds_a, np.int64))
        # Under "pic" the per-step entries are fresh POSITION counts; the
        # n· multiply happens here on host ints (no uint32 wrap).
        res.evals_by_phase["build"] = (
            (n if pic else 1) * int(np.asarray(evals_a, np.int64).sum())
            + n * self.k)
        if pic:
            res.evals_by_phase["build_cached"] = int(
                np.asarray(cached_a, np.int64).sum())
        jax.block_until_ready(dnear)
        res.wall_by_phase["build"] = time.perf_counter() - t0

        # SWAP — the fused per-iteration step; host reads accept/converge.
        t0 = time.perf_counter()
        delta_s = (self.delta if self.delta is not None
                   else 1.0 / (1000.0 * self.k * n))
        ck = self._step_key("swap", backend, n, delta_s, W)
        if ck not in _STEP_CACHE:
            _STEP_CACHE[ck] = self._make_swap_iter(be, n, delta_s, W)
        swap_iter = counted_dispatch(_STEP_CACHE[ck],
                                     res.dispatches_by_phase, "swap")
        loss = float(total_loss(data, med, metric=self.metric))
        swap_evals = 0
        swap_cached = 0
        converged = False
        carry = None
        for t in range(self.max_swaps):
            key, sub = jax.random.split(key)
            (best, new_loss_d, cand, new_carry, cache, fresh, cached,
             n_changed, used_exact) = swap_iter(
                data, data_sh, med, med_mask,
                _phase_key(self.seed, _SWAP_TAG, t), sub,
                lperm, lw, pidx_g, pw_g, cache, carry)
            # cache refresh (n·k) + candidate loss (n·k) + bandit rounds;
            # under "pic" fresh/n_changed are position/point counts and
            # the n· multiplies run on host ints (no uint32 wrap).
            swap_evals += 2 * n * self.k + (n if pic else 1) * int(fresh)
            swap_cached += int(cached) + n * int(n_changed)
            res.swap_exact_fallbacks += int(used_exact)
            if pic:
                carry = new_carry
            new_loss = float(new_loss_d)
            if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
                m_idx, x_idx = divmod(int(best), n)
                old = int(med[m_idx])
                med = cand
                med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
                res.swap_history.append((old, x_idx, new_loss))
                loss = new_loss
            else:
                converged = True
                break
        res.evals_by_phase["swap"] = swap_evals
        if pic:
            res.evals_by_phase["swap_cached"] = swap_cached
        res.wall_by_phase["swap"] = time.perf_counter() - t0

        res.medoids = np.asarray(med, np.int64)
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = sum(v for ph, v in res.evals_by_phase.items()
                                 if not ph.endswith("_cached"))
        res.cached_evals = sum(v for ph, v in res.evals_by_phase.items()
                               if ph.endswith("_cached"))
        return res


class MedoidCurator:
    """Embedding-space curation for the LM stack: cluster a (possibly
    sharded) embedding table with distributed BanditPAM, return medoid
    indices + assignments for coreset batch selection.

    The distributed path is gated on the *mesh's own* device count — a
    1-device mesh on a multi-device host runs the single-device solver,
    and a multi-device sub-mesh is honoured even when it covers only part
    of the host."""

    def __init__(self, k: int, mesh: Optional[Mesh] = None,
                 metric: str = "cosine", seed: int = 0,
                 backend: str = "auto"):
        self.k, self.mesh, self.metric, self.seed = k, mesh, metric, seed
        self.backend = backend

    def curate(self, embeddings) -> Tuple[np.ndarray, np.ndarray]:
        from .banditpam import BanditPAM
        emb = jnp.asarray(embeddings, jnp.float32)
        if self.mesh is not None and self.mesh.devices.size > 1:
            fit = DistributedBanditPAM(self.k, self.mesh, metric=self.metric,
                                       seed=self.seed,
                                       backend=self.backend).fit(emb)
        else:
            fit = BanditPAM(self.k, metric=self.metric, seed=self.seed,
                            baseline="leader", backend=self.backend).fit(emb)
        _, _, assign = medoid_cache(emb, jnp.asarray(fit.medoids),
                                    metric=self.metric)
        return fit.medoids, np.asarray(assign)
