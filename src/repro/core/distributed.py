"""Distributed BanditPAM: data-sharded references x replicated/sharded arms.

The multi-device execution of Algorithm 1 (docs/design.md hardware
adaptations #2/#4, mesh conventions §2):

* The reference set is sharded over the ``data`` (and ``pod``) mesh axes —
  each device owns ``ceil(n / n_shards)`` points (the sharded view is
  padded to a shard multiple with cyclic copies; padding rows sit past
  each shard's valid-draw range so they are never sampled, shards are
  weighted by their valid-row count, and all-padding shards carry weight
  0 — padding never reaches the statistics or the loss).
* Reference sampling is **stratified**: every round each shard contributes
  ``B / n_shards`` uniform draws from its *valid* local points, weighted
  by its stratum size so the estimator of mu_x stays unbiased even when
  the strata are uneven (docs/design.md hardware adaptation #4).  Draws
  are keyed by ``(seed, phase, selection/iteration, round, shard)`` — the
  round counter is folded in explicitly, so no two rounds of a fit can
  ever see identical reference batches (Theorem 1's confidence intervals
  assume fresh, independent batches per round).
* Each device computes the g-statistics of ALL arms against its local
  reference draw **through the registered ``StatsBackend``**
  (``repro.core.engine``): one backend ``pairwise`` block plus the
  backend's from-distances statistics (for ``"pallas"`` that is the tiled
  MXU pairwise kernel and the fused cached-stats SWAP kernel).  A single
  ``psum`` over the data axes — the only collective, owned by this layer,
  never by a backend — yields the global per-arm batch sums.  Arm
  elimination runs redundantly on every device (cheap vector math, saves
  a broadcast).
* The SWAP loop follows the fused per-iteration step shape of the
  single-device driver (docs/design.md hardware adaptation #5): one jit
  dispatch per iteration (medoid-cache refresh + sharded bandit search +
  candidate loss); the host only reads the accept/converge scalar.
* The hierarchical pod axis composes transparently: ``psum`` over
  ("pod", "data") is the cross-pod reduction.

``MedoidCurator`` is the LM-stack integration: it consumes embedding
shards (activations or dataset features) that already live sharded across
the data axis of a training/serving mesh and returns medoid indices +
assignments for data curation (examples/train_lm_curated.py).

The facade front-end is ``repro.api.KMedoids(solver="banditpam_dist",
mesh=..., backend=...)`` (``repro.api.registry``); without ``mesh=`` it
spans every local device (``default_mesh``).
"""

from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .adaptive import adaptive_search
from .engine import (exact_build_means, exact_swap_means, get_stats_backend,
                     medoid_cache, resolve_stats_backend, total_loss)
from .report import FitReport

__all__ = ["DistributedBanditPAM", "MedoidCurator", "default_mesh"]


if hasattr(jax, "shard_map"):                       # jax >= 0.6

    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    def _shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def default_mesh() -> Mesh:
    """One-axis ``("data",)`` mesh spanning every local device — the
    facade's default when ``KMedoids(solver="banditpam_dist")`` is given
    no ``mesh=``."""
    devs = np.asarray(jax.devices())
    return Mesh(devs.reshape(devs.size), ("data",))


# ---------------------------------------------------------------------------
# Sharded-sampler RNG chain
#
# Key schedule: PRNGKey(seed ^ phase_tag) -> fold(selection/iteration)
# -> fold(round) -> fold(shard).  Every level is folded in explicitly, so
# two distinct (phase, step, round, shard) tuples draw independent
# batches.  (Historically the chain keyed on the adaptive loop's
# ref_idx[0] and ignored the round counter entirely, so two rounds whose
# first sampled index collided silently reused identical reference
# batches — breaking the cross-round independence the Theorem 1
# confidence intervals assume.  tests/test_distributed_fit.py holds the
# regression.)
# ---------------------------------------------------------------------------

_BUILD_TAG = 0x5EED
_SWAP_TAG = 0x50A9


def _phase_key(seed: int, tag: int, step) -> jax.Array:
    """Base key of one bandit search: ``step`` is the BUILD selection
    index or the SWAP iteration counter."""
    return jax.random.fold_in(jax.random.PRNGKey(seed ^ tag), step)


def _round_key(phase_key: jax.Array, rnd) -> jax.Array:
    """Per-round key: folds ``adaptive_search``'s round counter."""
    return jax.random.fold_in(phase_key, rnd)


def _shard_draws(round_key: jax.Array, ax, n_valid, b_loc: int) -> jnp.ndarray:
    """Shard ``ax``'s stratified draw: ``b_loc`` uniform indices into its
    valid local rows (``max(n_valid, 1)`` guards all-padding shards, whose
    stratum weight is 0 anyway)."""
    kk = jax.random.fold_in(round_key, ax)
    return jax.random.randint(kk, (b_loc,), 0, jnp.maximum(n_valid, 1))


# Compiled phase steps, shared across instances: jax.jit's cache is keyed
# on the function object, so rebuilding the step closures every fit would
# recompile both phases.  A module-level table (like the single-device
# driver's module-level jits) makes repeated fits retrace-free even when
# each fit constructs a fresh estimator — the facade registry does exactly
# that.  Keys cover everything the closures capture (see ``_step_key``).
_STEP_CACHE: dict = {}


class DistributedBanditPAM:
    """BanditPAM over a sharded reference set.

    data: [n, d] array (host); sharded internally over the mesh's data
    axes (padded to a shard multiple when n is uneven — padding rows are
    masked out of sampling, statistics, and loss).  Semantics match
    `BanditPAM` (same medoids as PAM w.h.p.); the sampling schedule
    differs (stratified per shard), so seeds are not comparable with the
    single-device class.

    ``backend`` selects the shard-local g-statistics path
    (``repro.core.engine``): ``"auto"`` | ``"pallas"`` | ``"jnp"`` or any
    registered stats backend.  The ``psum`` composition lives here; the
    backends stay collective-free.
    """

    def __init__(self, k: int, mesh: Mesh, metric: str = "l2",
                 batch_size: int = 128, delta: Optional[float] = None,
                 max_swaps: Optional[int] = None, seed: int = 0,
                 backend: str = "auto"):
        self.k = int(k)
        self.mesh = mesh
        self.metric = metric
        self.daxes = _data_axes(mesh)
        if not self.daxes:
            raise ValueError(f"mesh has no data axes; axis names must "
                             f"include 'data' and/or 'pod', got "
                             f"{mesh.axis_names}")
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.daxes]))
        if batch_size % self.n_shards:
            batch_size += self.n_shards - batch_size % self.n_shards
        self.batch_size = batch_size
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed
        self.backend = backend

    def _step_key(self, phase: str, backend: str, n: int, delta: float):
        """Cache key covering everything the compiled phase closures
        capture: mesh (axes, shard count), backend, shapes, metric, and
        the static batch/confidence parameters."""
        return (phase, self.mesh, backend, n, self.k, self.metric,
                self.batch_size, delta)

    # -- sharded stats ----------------------------------------------------
    def _shard_data(self, data: jnp.ndarray) -> jnp.ndarray:
        """The sharded reference view: rows padded to a shard multiple
        with cyclic copies (real points, so every metric stays NaN-free;
        the stratum weights below zero them out of the statistics).  The
        modular gather also covers n smaller than the mesh, where the
        padding wraps around the data more than once."""
        n = data.shape[0]
        n_pad = self._n_loc(n) * self.n_shards
        if n_pad != n:
            data = data[jnp.arange(n_pad) % n]
        return jax.device_put(
            data, NamedSharding(self.mesh, P(self.daxes, None)))

    def _n_loc(self, n: int) -> int:
        return -(-n // self.n_shards)

    def _flat_ax(self):
        """The shard's flattened index over the (pod, data) strata."""
        daxes = self.daxes
        if len(daxes) == 1:
            return lambda: jax.lax.axis_index(daxes[0])
        m2 = self.mesh.shape[daxes[1]]
        return lambda: (jax.lax.axis_index(daxes[0]) * m2
                        + jax.lax.axis_index(daxes[1]))

    def _stratum(self, n: int, n_loc: int, ax):
        """(valid row count, stratum weight) of shard ``ax``.

        The weight ``v·n_shards/n`` makes the equal-draws-per-shard
        estimator unbiased under uneven strata: each draw of shard s
        estimates mean_s, and sum_s (B/n_shards)·w_s·mean_s / B =
        sum_s (v_s/n)·mean_s — the global mean.  Even split ⇒ w ≡ 1."""
        v = jnp.clip(n - ax * n_loc, 0, n_loc)
        return v, v.astype(jnp.float32) * self.n_shards / n

    def _build_smap(self, be, n: int):
        """Sharded BUILD statistics: ``smap(data_f, data_l, dnear_f,
        round_key, lead) -> (sums, sqsums, cross)``, psum'd over the data
        axes.  The shard-local stats go through the stats backend; only
        the reduction is owned here."""
        metric = self.metric
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()

        def local(data_f, data_l, dnear_f, rkey, lead):
            ax = axfn()
            v, cs = self._stratum(n, n_loc, ax)
            idx = _shard_draws(rkey, ax, v, b_loc)
            gidx = jnp.minimum(ax * n_loc + idx, n - 1)
            w = jnp.ones((b_loc,), jnp.float32)
            dxy = be.pairwise(data_f, data_l[idx], metric=metric)  # [n, b_loc]
            s, q, c = be.build_stats_from_d(dxy, dnear_f[gidx], w, lead)
            return (jax.lax.psum(s * cs, self.daxes),
                    jax.lax.psum(q * (cs * cs), self.daxes),
                    jax.lax.psum(c * (cs * cs), self.daxes))

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(), P(), P()),
                          out_specs=(P(), P(), P()))

    def _swap_smap(self, be, n: int):
        """Sharded SWAP statistics over the flattened (medoid, candidate)
        arm set: ``smap(data_f, data_l, d1_f, d2_f, assign_f, round_key,
        lead)``.  On the Pallas backend the from-distances stats hit the
        fused cached-stats kernel."""
        metric = self.metric
        k = self.k
        b_loc = self.batch_size // self.n_shards
        n_loc = self._n_loc(n)
        axfn = self._flat_ax()

        def local(data_f, data_l, d1_f, d2_f, a_f, rkey, lead):
            ax = axfn()
            v, cs = self._stratum(n, n_loc, ax)
            idx = _shard_draws(rkey, ax, v, b_loc)
            gidx = jnp.minimum(ax * n_loc + idx, n - 1)
            w = jnp.ones((b_loc,), jnp.float32)
            dxy = be.pairwise(data_f, data_l[idx], metric=metric)
            s, q, c = be.swap_stats_from_d(dxy, d1_f[gidx], d2_f[gidx],
                                           a_f[gidx], w, k, lead)
            return (jax.lax.psum(s * cs, self.daxes),
                    jax.lax.psum(q * (cs * cs), self.daxes),
                    jax.lax.psum(c * (cs * cs), self.daxes))

        return _shard_map(local, self.mesh,
                          in_specs=(P(), P(self.daxes, None), P(), P(), P(),
                                    P(), P()),
                          out_specs=(P(), P(), P()))

    # -- fused phase steps -----------------------------------------------
    def _make_build_step(self, be, n: int, delta: float):
        """One BUILD medoid selection as ONE jit dispatch: sharded bandit
        search + d_near/medoid-mask update on device; the host only reads
        the winning index.  ``data``/``data_sh`` are jit arguments (not
        closure constants) so XLA never constant-folds distance blocks at
        compile time."""
        smap = self._build_smap(be, n)
        metric = self.metric
        B = self.batch_size

        @jax.jit
        def step(data, data_sh, dnear, med_mask, phase_key, search_key):
            def stats_fn(ref_idx, w, lead, rnd):
                # The adaptive loop's own (replacement-mode) draw is
                # ignored; each shard draws locally from the round key.
                return smap(data, data_sh, dnear, _round_key(phase_key, rnd),
                            lead)

            def exact_fn():
                return exact_build_means(be, data, dnear, metric=metric)

            sr = adaptive_search(search_key, stats_fn=stats_fn,
                                 exact_fn=exact_fn, n_arms=n, n_ref=n,
                                 batch_size=B, delta=delta,
                                 active_init=jnp.logical_not(med_mask),
                                 sampling="replacement", baseline="leader")
            m = sr.best
            dnear2 = jnp.minimum(
                dnear, be.pairwise(data[m][None, :], data, metric=metric)[0])
            med_mask2 = med_mask.at[m].set(True)
            return m, dnear2, med_mask2, sr.n_evals, sr.rounds, sr.used_exact

        return step

    def _make_swap_iter(self, be, n: int, delta: float):
        """One SWAP iteration as ONE fused jit dispatch (hardware
        adaptation #5 shape): medoid-cache refresh + sharded bandit search
        + candidate loss; only the accept/converge scalar is read on
        host."""
        smap = self._swap_smap(be, n)
        metric = self.metric
        B = self.batch_size
        k = self.k

        @jax.jit
        def swap_iter(data, data_sh, medoids, med_mask, phase_key,
                      search_key):
            d1, d2, assign = medoid_cache(data, medoids, metric=metric)

            def stats_fn(ref_idx, w, lead, rnd):
                return smap(data, data_sh, d1, d2, assign,
                            _round_key(phase_key, rnd), lead)

            def exact_fn():
                return exact_swap_means(be, data, d1, d2, assign, k,
                                        metric=metric)

            active0 = jnp.tile(jnp.logical_not(med_mask)[None, :],
                               (k, 1)).reshape(-1)

            def count_fn(active):
                # FastPAM1: one distance per (x, y) serves all k arms (·, x).
                any_x = jnp.any(active.reshape(k, n), axis=0)
                return jnp.sum(any_x.astype(jnp.uint32))

            sr = adaptive_search(search_key, stats_fn=stats_fn,
                                 exact_fn=exact_fn, n_arms=k * n, n_ref=n,
                                 batch_size=B, delta=delta,
                                 active_init=active0, count_fn=count_fn,
                                 sampling="replacement", baseline="leader")
            m_idx = sr.best // n
            x_idx = sr.best % n
            cand = medoids.at[m_idx].set(x_idx)
            new_loss = total_loss(data, cand, metric=metric)
            return (sr.best, new_loss, cand, sr.n_evals, sr.rounds,
                    sr.used_exact)

        return swap_iter

    # -- fit --------------------------------------------------------------
    def fit(self, data) -> FitReport:
        data = jnp.asarray(data, jnp.float32)
        n = data.shape[0]
        if n <= self.k:
            raise ValueError("need n > k")
        backend = resolve_stats_backend(self.backend, self.metric)
        be = get_stats_backend(backend)
        data_sh = self._shard_data(data)
        key = jax.random.PRNGKey(self.seed)
        res = FitReport(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0,
                        solver="banditpam_dist", metric=str(self.metric))

        # BUILD — one jit dispatch per selection, replacement-mode bandit
        # rounds over stratified shard-local draws.
        t0 = time.perf_counter()
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        ck = self._step_key("build", backend, n, delta)
        if ck not in _STEP_CACHE:
            _STEP_CACHE[ck] = self._make_build_step(be, n, delta)
        build_step = _STEP_CACHE[ck]
        dnear = jnp.full((n,), jnp.inf, jnp.float32)
        med_mask = jnp.zeros((n,), jnp.bool_)
        medoids = []
        build_evals = 0
        for i in range(self.k):
            key, sub = jax.random.split(key)
            m, dnear, med_mask, n_evals, rounds, _ = build_step(
                data, data_sh, dnear, med_mask,
                _phase_key(self.seed, _BUILD_TAG, i), sub)
            medoids.append(int(m))
            build_evals += int(n_evals) + n          # + n: d_near update
            res.build_rounds.append(int(rounds))
        med = jnp.asarray(medoids, jnp.int32)
        res.evals_by_phase["build"] = build_evals
        jax.block_until_ready(dnear)
        res.wall_by_phase["build"] = time.perf_counter() - t0

        # SWAP — the fused per-iteration step; host reads accept/converge.
        t0 = time.perf_counter()
        delta_s = (self.delta if self.delta is not None
                   else 1.0 / (1000.0 * self.k * n))
        ck = self._step_key("swap", backend, n, delta_s)
        if ck not in _STEP_CACHE:
            _STEP_CACHE[ck] = self._make_swap_iter(be, n, delta_s)
        swap_iter = _STEP_CACHE[ck]
        loss = float(total_loss(data, med, metric=self.metric))
        swap_evals = 0
        converged = False
        for t in range(self.max_swaps):
            key, sub = jax.random.split(key)
            best, new_loss_d, cand, n_evals, rounds, used_exact = swap_iter(
                data, data_sh, med, med_mask,
                _phase_key(self.seed, _SWAP_TAG, t), sub)
            # cache refresh (n·k) + candidate loss (n·k) + bandit rounds
            swap_evals += 2 * n * self.k + int(n_evals)
            res.swap_exact_fallbacks += int(used_exact)
            new_loss = float(new_loss_d)
            if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
                m_idx, x_idx = divmod(int(best), n)
                old = int(med[m_idx])
                med = cand
                med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
                res.swap_history.append((old, x_idx, new_loss))
                loss = new_loss
            else:
                converged = True
                break
        res.evals_by_phase["swap"] = swap_evals
        res.wall_by_phase["swap"] = time.perf_counter() - t0

        res.medoids = np.asarray(med, np.int64)
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = sum(v for ph, v in res.evals_by_phase.items()
                                 if not ph.endswith("_cached"))
        return res


class MedoidCurator:
    """Embedding-space curation for the LM stack: cluster a (possibly
    sharded) embedding table with distributed BanditPAM, return medoid
    indices + assignments for coreset batch selection.

    The distributed path is gated on the *mesh's own* device count — a
    1-device mesh on a multi-device host runs the single-device solver,
    and a multi-device sub-mesh is honoured even when it covers only part
    of the host."""

    def __init__(self, k: int, mesh: Optional[Mesh] = None,
                 metric: str = "cosine", seed: int = 0,
                 backend: str = "auto"):
        self.k, self.mesh, self.metric, self.seed = k, mesh, metric, seed
        self.backend = backend

    def curate(self, embeddings) -> Tuple[np.ndarray, np.ndarray]:
        from .banditpam import BanditPAM
        emb = jnp.asarray(embeddings, jnp.float32)
        if self.mesh is not None and self.mesh.devices.size > 1:
            fit = DistributedBanditPAM(self.k, self.mesh, metric=self.metric,
                                       seed=self.seed,
                                       backend=self.backend).fit(emb)
        else:
            fit = BanditPAM(self.k, metric=self.metric, seed=self.seed,
                            baseline="leader", backend=self.backend).fit(emb)
        _, _, assign = medoid_cache(emb, jnp.asarray(fit.medoids),
                                    metric=self.metric)
        return fit.medoids, np.asarray(assign)
