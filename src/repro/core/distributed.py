"""Distributed BanditPAM: data-sharded references x replicated/sharded arms.

The multi-device execution of Algorithm 1 (docs/design.md hardware
adaptations #2/#4, mesh conventions §2):

* The reference set is sharded over the ``data`` (and ``pod``) mesh axes —
  each device owns ``n / n_shards`` points.
* Reference sampling is **stratified**: every round each shard contributes
  ``B / n_shards`` uniform draws from its local points (equal-size strata
  ⇒ the estimator of mu_x stays unbiased; docs/design.md hardware adaptation #4).
* Each device computes the g-statistics of ALL arms against its local
  reference draw; a single ``psum`` over the data axes yields the global
  per-arm batch sums.  Arm elimination runs redundantly on every device
  (cheap vector math, saves a broadcast).
* The hierarchical pod axis composes transparently: ``psum`` over
  ("pod", "data") is the cross-pod reduction.

``MedoidCurator`` is the LM-stack integration: it consumes embedding
shards (activations or dataset features) that already live sharded across
the data axis of a training/serving mesh and returns medoid indices +
assignments for data curation (examples/train_lm_curated.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .adaptive import adaptive_search
from .banditpam import FitResult
from .distances import get_metric
from .engine import _build_g, _swap_batch_stats


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class DistributedBanditPAM:
    """BanditPAM over a sharded reference set.

    data: [n, d] array (host); sharded internally over the mesh's data axes.
    Semantics match `BanditPAM` (same medoids as PAM w.h.p.); the sampling
    schedule differs (stratified per shard), so seeds are not comparable
    with the single-device class.
    """

    def __init__(self, k: int, mesh: Mesh, metric: str = "l2",
                 batch_size: int = 128, delta: Optional[float] = None,
                 max_swaps: Optional[int] = None, seed: int = 0):
        self.k = int(k)
        self.mesh = mesh
        self.metric = metric
        self.daxes = _data_axes(mesh)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.daxes]))
        if batch_size % self.n_shards:
            batch_size += self.n_shards - batch_size % self.n_shards
        self.batch_size = batch_size
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed

    # -- sharded stats ----------------------------------------------------
    def _shard_data(self, data: jnp.ndarray) -> jnp.ndarray:
        return jax.device_put(
            data, NamedSharding(self.mesh, P(self.daxes, None)))

    def _build_stats_fn(self, data_sh, dnear, n: int):
        """stats_fn(ref_idx, w, lead) with shard-local stratified sampling.

        ref_idx here is reinterpreted: the adaptive loop's sampled global
        indices are ignored; instead each shard draws B/n_shards local
        rows keyed by the round's first index (deterministic)."""
        metric = self.metric
        b_loc = self.batch_size // self.n_shards
        daxes = self.daxes
        dist = get_metric(metric)
        n_loc = n // self.n_shards

        def local(data_l, dnear_l, key, lead):
            ax = jax.lax.axis_index(daxes[0]) if len(daxes) == 1 else (
                jax.lax.axis_index(daxes[0]) * self.mesh.shape[daxes[1]]
                + jax.lax.axis_index(daxes[1]))
            kk = jax.random.fold_in(key, ax)
            idx = jax.random.randint(kk, (b_loc,), 0, n_loc)
            y = data_l[idx]
            g = _build_g(dist(data_sh, y), dnear_l[idx])    # [n, b_loc]
            sums = jax.lax.psum(jnp.sum(g, 1), daxes)
            sq = jax.lax.psum(jnp.sum(g * g, 1), daxes)
            cross = jax.lax.psum(g @ g[lead], daxes)
            return sums, sq, cross

        # data_sh (targets) is replicated inside shard_map via closure; the
        # sharded view provides the local reference rows.
        smap = jax.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(self.daxes, None), P(self.daxes), P(), P()),
            out_specs=(P(), P(), P()), check_vma=False)

        def stats_fn(ref_idx, w, lead, rnd):
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5eed),
                                     ref_idx[0])
            return smap(data_sh, dnear, key, lead)

        return stats_fn

    def _swap_stats_fn(self, data_sh, d1, d2, assign, n: int):
        metric = self.metric
        k = self.k
        b_loc = self.batch_size // self.n_shards
        daxes = self.daxes
        dist = get_metric(metric)
        n_loc = n // self.n_shards

        def local(data_l, d1_l, d2_l, a_l, key, lead):
            ax = jax.lax.axis_index(daxes[0]) if len(daxes) == 1 else (
                jax.lax.axis_index(daxes[0]) * self.mesh.shape[daxes[1]]
                + jax.lax.axis_index(daxes[1]))
            kk = jax.random.fold_in(key, ax)
            idx = jax.random.randint(kk, (b_loc,), 0, n_loc)
            dxy = dist(data_sh, data_l[idx])
            w = jnp.ones((b_loc,), dxy.dtype)
            sums, sq, cross = _swap_batch_stats(
                dxy, d1_l[idx], d2_l[idx], a_l[idx], w, k, lead=lead)
            return (jax.lax.psum(sums, daxes), jax.lax.psum(sq, daxes),
                    jax.lax.psum(cross, daxes))

        smap = jax.shard_map(
            local, mesh=self.mesh,
            in_specs=(P(self.daxes, None), P(self.daxes), P(self.daxes),
                      P(self.daxes), P(), P()),
            out_specs=(P(), P(), P()), check_vma=False)

        def stats_fn(ref_idx, w, lead, rnd):
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x50a9),
                                     ref_idx[0])
            return smap(data_sh, d1, d2, assign, key, lead)

        return stats_fn

    # -- fit --------------------------------------------------------------
    def fit(self, data) -> FitResult:
        data = jnp.asarray(data, jnp.float32)
        n = data.shape[0]
        assert n % self.n_shards == 0, (n, self.n_shards)
        dist = get_metric(self.metric)
        data_sh = self._shard_data(data)
        key = jax.random.PRNGKey(self.seed)
        res = FitResult(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0)

        # BUILD — replacement-mode sampling (stratified draws), exact
        # fallback disabled by supplying the exact pass distributed too.
        dnear = jnp.full((n,), jnp.inf, jnp.float32)
        med_mask = jnp.zeros((n,), jnp.bool_)
        medoids = []
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        evals = 0
        for _ in range(self.k):
            key, sub = jax.random.split(key)
            stats_fn = self._build_stats_fn(data_sh, dnear, n)

            def exact_fn():
                dxy = dist(data, data)
                g = _build_g(dxy, dnear)
                return jnp.mean(g, axis=1)

            sr = adaptive_search(sub, stats_fn=stats_fn, exact_fn=exact_fn,
                                 n_arms=n, n_ref=n,
                                 batch_size=self.batch_size, delta=delta,
                                 active_init=jnp.logical_not(med_mask),
                                 sampling="replacement", baseline="leader")
            m = int(sr.best)
            medoids.append(m)
            med_mask = med_mask.at[m].set(True)
            dnear = jnp.minimum(dnear, dist(data[m][None], data)[0])
            evals += int(sr.n_evals) + n
        med = jnp.asarray(medoids, jnp.int32)

        # SWAP
        loss = float(jnp.sum(jnp.min(dist(data, data[med]), 1)))
        delta_s = self.delta if self.delta is not None else 1.0 / (1000.0 * self.k * n)
        converged = False
        for _ in range(self.max_swaps):
            dmat = dist(data, data[med])
            assign = jnp.argmin(dmat, 1).astype(jnp.int32)
            d1 = jnp.min(dmat, 1)
            d2 = jnp.min(dmat.at[jnp.arange(n), assign].set(jnp.inf), 1)
            evals += n * self.k
            key, sub = jax.random.split(key)
            stats_fn = self._swap_stats_fn(data_sh, d1, d2, assign, n)

            def exact_fn():
                dxy = dist(data, data)
                w = jnp.ones((n,), jnp.float32)
                s, _, _ = _swap_batch_stats(dxy, d1, d2, assign, w, self.k,
                                            lead=jnp.int32(0))
                return s / n

            active0 = jnp.tile(jnp.logical_not(med_mask)[None], (self.k, 1)
                               ).reshape(-1)
            sr = adaptive_search(sub, stats_fn=stats_fn, exact_fn=exact_fn,
                                 n_arms=self.k * n, n_ref=n,
                                 batch_size=self.batch_size, delta=delta_s,
                                 active_init=active0,
                                 sampling="replacement", baseline="leader")
            evals += int(sr.n_evals)
            m_idx, x_idx = divmod(int(sr.best), n)
            cand = med.at[m_idx].set(x_idx)
            new_loss = float(jnp.sum(jnp.min(dist(data, data[cand]), 1)))
            evals += n * self.k
            if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
                old = int(med[m_idx])
                med = cand
                med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
                res.swap_history.append((old, x_idx, new_loss))
                loss = new_loss
            else:
                converged = True
                break

        res.medoids = np.asarray(med)
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = evals
        return res


class MedoidCurator:
    """Embedding-space curation for the LM stack: cluster a (possibly
    sharded) embedding table with distributed BanditPAM, return medoid
    indices + assignments for coreset batch selection."""

    def __init__(self, k: int, mesh: Optional[Mesh] = None,
                 metric: str = "cosine", seed: int = 0):
        self.k, self.mesh, self.metric, self.seed = k, mesh, metric, seed

    def curate(self, embeddings) -> Tuple[np.ndarray, np.ndarray]:
        from .banditpam import BanditPAM, medoid_cache
        emb = jnp.asarray(embeddings, jnp.float32)
        if self.mesh is not None and len(jax.devices()) > 1:
            fit = DistributedBanditPAM(self.k, self.mesh, metric=self.metric,
                                       seed=self.seed).fit(emb)
        else:
            fit = BanditPAM(self.k, metric=self.metric, seed=self.seed,
                            baseline="leader").fit(emb)
        _, _, assign = medoid_cache(emb, jnp.asarray(fit.medoids),
                                    metric=self.metric)
        return fit.medoids, np.asarray(assign)
