"""BanditPAM: the paper's algorithm — BUILD + SWAP driven by Algorithm 1.

Faithful to the paper:

* BUILD (Eq. 6): arms = candidate points, ``g_x(y) = (d(x,y) − d_near(y)) ∧ 0``
  against the cached nearest-medoid distance; the first assignment uses
  ``g_x(y) = d(x,y)`` (Eq. 4 with an empty medoid set).
* SWAP (Eq. 7 + Appendix Eq. 12 / FastPAM1): arms = (medoid m, candidate x)
  pairs.  One distance ``d(x,y)`` serves all k arms ``(·, x)`` via the cached
  ``d₁, d₂`` and cluster assignment — evaluated here as a base term plus a
  one-hot matmul correction, which never materialises a ``[k, n, B]`` tensor:

      g_{m,x}(y) = −d₁(y) + 1[y∉C_m]·min(d₁(y), d(x,y))
                           + 1[y∈C_m]·min(d₂(y), d(x,y))
                 = base_x(y) + 1[y∈C_m]·corr_x(y)
      base_x(y) = min(d₁(y), d(x,y)) − d₁(y)
      corr_x(y) = min(d₂(y), d(x,y)) − min(d₁(y), d(x,y))

* σ_x re-estimated from the first batch of every Algorithm 1 call (Eq. 11,
  Appendix 1.2), B = 100, δ = 1/(1000·|S_tar|) by default (§3.2).
* SWAP iterations repeat until the chosen swap no longer improves the exact
  loss, with a hard cap T (paper §4 Remark 1).

Distance-evaluation accounting (the paper's headline metric) is algorithmic:
each bandit round pays ``#active-arms × B`` in BUILD and
``#distinct-active-candidates × B`` in SWAP (FastPAM1 sharing), cache
(re)computation pays ``n·k``, and the d_near update after each BUILD
assignment pays ``n`` — exactly the ledger of the reference implementation.

Beyond the paper, ``BanditPAM(reuse="pic")`` enables the BanditPAM++
(Tiwari et al. 2023) SWAP-phase reuse engine:

* **PIC** — every search samples the SAME fixed reference permutation, and
  the distance columns it consumes are materialised once into a lazily
  grown cache (``_PicCache``); later searches replay those rounds for free.
* **Virtual arms** — per-arm Σg / Σg² from swap iteration *t* are carried
  into iteration *t+1* and repaired only where the accepted swap moved a
  reference point's (d1, d2, assign); per changed point that touches the
  shared base term plus at most the point's old and new cluster rows
  (``_carry_delta``).  A search seeded this way usually resolves its argmin
  from the carried exact prefix without sampling at all.

Under ``reuse="pic"`` the ledger splits into fresh vs cached: fresh pays
``n`` per newly materialised cache column (plus the ``n·k`` cache/loss
terms), cached tallies carried-prefix replays, warm rounds and delta
repairs.  ``reuse="none"`` reproduces the original ledger exactly.
"""

from __future__ import annotations

import functools
import warnings
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import SearchResult, adaptive_search
from .distances import get_metric
from .report import FitReport

_EXACT_CHUNK = 512  # reference-chunk size for exact fallback passes


# ---------------------------------------------------------------------------
# Shared cache / loss helpers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric",))
def medoid_cache(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d1 (nearest-medoid dist), d2 (second nearest), assignment; [n] each."""
    dmat = get_metric(metric)(data, data[medoids])          # [n, k]
    assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    d1 = jnp.min(dmat, axis=1)
    dmat2 = dmat.at[jnp.arange(dmat.shape[0]), assign].set(jnp.inf)
    d2 = jnp.min(dmat2, axis=1)
    return d1, d2, assign


@functools.partial(jax.jit, static_argnames=("metric",))
def total_loss(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str) -> jnp.ndarray:
    dmat = get_metric(metric)(data, data[medoids])
    return jnp.sum(jnp.min(dmat, axis=1))


def _ref_chunks(n_ref: int, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static index/weight tiling of [0, n_ref) into equal chunks."""
    n_chunks = -(-n_ref // chunk)
    idx = np.arange(n_chunks * chunk)
    w = (idx < n_ref).astype(np.float32)
    idx = np.minimum(idx, n_ref - 1)
    return idx.reshape(n_chunks, chunk), w.reshape(n_chunks, chunk)


# ---------------------------------------------------------------------------
# BUILD
# ---------------------------------------------------------------------------

def _build_g(dxy: jnp.ndarray, dnear_b: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6 with the Eq. 4 special-case for the first assignment."""
    dn = dnear_b[None, :]
    return jnp.where(jnp.isinf(dn), dxy, jnp.minimum(dxy - dn, 0.0))


@functools.partial(jax.jit,
                   static_argnames=("metric", "batch_size", "delta", "sampling",
                                    "baseline"))
def _build_search(data: jnp.ndarray, dnear: jnp.ndarray, med_mask: jnp.ndarray,
                  key: jax.Array, *, metric: str, batch_size: int,
                  delta: float, sampling: str = "permutation",
                  baseline: str = "none", perm=None, dwarm=None,
                  free_rounds=0) -> SearchResult:
    n = data.shape[0]
    dist = get_metric(metric)

    def stats_fn(ref_idx, w, lead, rnd):
        if dwarm is None:
            dxy = dist(data, data[ref_idx])
        else:
            # paper App 2.2 cache: warm rounds read precomputed distance
            # columns (same fixed permutation across every search call)
            dxy = jax.lax.cond(
                rnd < free_rounds,
                lambda _: jax.lax.dynamic_slice_in_dim(
                    dwarm, rnd * batch_size, batch_size, 1),
                lambda _: dist(data, data[ref_idx]), None)
        g = _build_g(dxy, dnear[ref_idx]) * w[None, :]             # [n, B]
        cross = g @ g[lead]
        return jnp.sum(g, axis=1), jnp.sum(g * g, axis=1), cross

    def exact_fn():
        idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, wc = iw
            g = _build_g(dist(data, data[i]), dnear[i])
            return acc + jnp.sum(g * wc[None, :], axis=1), None

        sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32), (idx, w))
        return sums / n

    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=n, n_ref=n, batch_size=batch_size,
                           delta=delta, active_init=jnp.logical_not(med_mask),
                           sampling=sampling, baseline=baseline, perm=perm,
                           free_rounds=free_rounds)


# ---------------------------------------------------------------------------
# SWAP (FastPAM1 fused form)
# ---------------------------------------------------------------------------

def _swap_terms(dxy: jnp.ndarray, d1_b: jnp.ndarray, d2_b: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    base = jnp.minimum(dxy, d1_b[None, :]) - d1_b[None, :]
    corr = jnp.minimum(dxy, d2_b[None, :]) - jnp.minimum(dxy, d1_b[None, :])
    return base, corr


def _swap_batch_stats(dxy, d1_b, d2_b, a_b, w, k, lead=None):
    """Per-arm (m·n + x) sums, square-sums (and optional leader cross-sums)
    over a reference batch.

    g = base + 1[assign==m]·corr  ⇒
      Σ g        = Σ base + Σ_{y∈C_m} corr
      Σ g²       = Σ base² + Σ_{y∈C_m} (2·base·corr + corr²)
      Σ g·g_lead = Σ base·g_lead + Σ_{y∈C_m} corr·g_lead
    The C_m-restricted sums are one-hot matmuls (MXU-shaped).
    """
    n = dxy.shape[0]
    base, corr = _swap_terms(dxy, d1_b, d2_b)
    # weights are {0,1} (padding mask), so w² = w and masking base once is
    # enough for every product below.
    base = base * w[None, :]
    onehot = jax.nn.one_hot(a_b, k, dtype=dxy.dtype) * w[:, None]   # [B, k]
    sums = jnp.sum(base, axis=1)[None, :] + (corr @ onehot).T       # [k, n]
    sq_base = jnp.sum(base * base, axis=1)
    sq_cross = 2.0 * base * corr + corr * corr
    sqsums = sq_base[None, :] + (sq_cross @ onehot).T
    if lead is None:
        return sums.reshape(-1), sqsums.reshape(-1)
    m_l, x_l = lead // n, lead % n
    g_lead = base[x_l] + onehot[:, m_l] * corr[x_l]                 # [B], w-masked
    cross = (base @ g_lead)[None, :] + ((corr * g_lead[None, :]) @ onehot).T
    return sums.reshape(-1), sqsums.reshape(-1), cross.reshape(-1)


@functools.partial(jax.jit,
                   static_argnames=("metric", "batch_size", "delta", "k",
                                    "sampling", "baseline", "early_stop"))
def _swap_search(data: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                 assign: jnp.ndarray, med_mask: jnp.ndarray, key: jax.Array,
                 *, metric: str, batch_size: int, delta: float, k: int,
                 sampling: str = "permutation", baseline: str = "none",
                 early_stop: bool = False, perm=None, dwarm=None,
                 free_rounds=0, init_sums=None, init_sqsums=None,
                 init_rounds=0) -> SearchResult:
    n = data.shape[0]
    dist = get_metric(metric)

    def stats_fn(ref_idx, w, lead, rnd):
        if dwarm is None:
            dxy = dist(data, data[ref_idx])                  # [n, B]
        else:
            dxy = jax.lax.cond(
                rnd < free_rounds,
                lambda _: jax.lax.dynamic_slice_in_dim(
                    dwarm, rnd * batch_size, batch_size, 1),
                lambda _: dist(data, data[ref_idx]), None)
        return _swap_batch_stats(dxy, d1[ref_idx], d2[ref_idx],
                                 assign[ref_idx], w, k, lead=lead)

    def exact_fn():
        idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, wc = iw
            dxy = dist(data, data[i])
            s, _ = _swap_batch_stats(dxy, d1[i], d2[i], assign[i], wc, k)
            return acc + s, None

        sums, _ = jax.lax.scan(body, jnp.zeros((k * n,), jnp.float32), (idx, w))
        return sums / n

    # Candidates that are already medoids are not valid swap targets.
    active0 = jnp.tile(jnp.logical_not(med_mask)[None, :], (k, 1)).reshape(-1)

    def count_fn(active):
        # FastPAM1: one distance per (x, y) pair serves all k arms (·, x).
        any_x = jnp.any(active.reshape(k, n), axis=0)
        return jnp.sum(any_x.astype(jnp.uint32))

    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=k * n, n_ref=n, batch_size=batch_size,
                           delta=delta, active_init=active0, count_fn=count_fn,
                           sampling=sampling, baseline=baseline,
                           stop_when_positive=early_stop, perm=perm,
                           free_rounds=free_rounds, init_sums=init_sums,
                           init_sqsums=init_sqsums, init_rounds=init_rounds)


# ---------------------------------------------------------------------------
# BanditPAM++ SWAP-phase reuse engine (virtual arms + PIC)
# ---------------------------------------------------------------------------

class _PicCache:
    """Permutation-invariant cache (BanditPAM++, Tiwari et al. 2023).

    One FIXED random permutation of the reference set is shared by every
    BUILD/SWAP search of a fit, and the distance columns ``d(·, perm[j])``
    consumed by any search are materialised once and kept.  Rounds below
    the high-water mark are then served to ``adaptive_search`` as *cached*
    rounds (zero fresh evaluations) by every later search — valid because
    the columns depend only on the data and the permutation, never on the
    evolving medoid set.

    The cache grows lazily in whole bandit rounds (unlike the upfront
    ``cache_cols`` warm block, nothing is paid for rounds no search ever
    reaches).  ``view()`` pads the width to a ``PAD_ROUNDS`` multiple so
    jit re-traces at most every ``PAD_ROUNDS`` growth steps.
    """

    PAD_ROUNDS = 8

    def __init__(self, data: jnp.ndarray, perm: jnp.ndarray, batch_size: int,
                 metric: str):
        self.data = data
        self.metric = metric
        self.B = int(batch_size)
        n = int(data.shape[0])
        self.n = n
        self.n_rounds_max = -(-n // self.B)
        total = self.n_rounds_max * self.B
        perm_np = np.asarray(perm).astype(np.int32)
        # Same tiling as adaptive_search: positions >= n are w=0 padding.
        self.perm = jnp.asarray(perm_np)
        self.perm_idx = jnp.asarray(np.tile(perm_np, -(-total // n))[:total])
        self.perm_w = jnp.asarray((np.arange(total) < n).astype(np.float32))
        self.hw_rounds = 0
        self._cols = np.zeros((n, 0), np.float32)
        self._view = None      # memoised device array
        self._view_hw = 0      # rounds materialised into _view

    def ensure(self, rounds: int) -> int:
        """Materialise columns for rounds ``[hw, rounds)``; returns the fresh
        distance evaluations paid (n per new effective reference position —
        a full column, which is what makes the position free for *every* arm
        of every later search).

        Note the ledger counts these evaluations once, but on this jit'd
        driver the wall-clock compute for a newly reached round is ~2×: the
        search already computed the column inside ``stats_fn`` and cannot
        write it out of the ``while_loop``, so materialisation recomputes
        it here.  A TPU deployment with kernel-side write-through would pay
        it once, which is what the algorithmic ledger models."""
        rounds = min(int(rounds), self.n_rounds_max)
        if rounds <= self.hw_rounds:
            return 0
        lo, hi = self.hw_rounds * self.B, rounds * self.B
        pos = np.arange(lo, hi)
        eff = pos < self.n
        new = np.zeros((self.n, hi - lo), np.float32)
        if eff.any():
            idx = np.asarray(self.perm_idx)[lo:hi][eff]
            cols = get_metric(self.metric)(self.data, self.data[jnp.asarray(idx)])
            new[:, eff] = np.asarray(cols)
        self._cols = np.concatenate([self._cols, new], axis=1)
        self.hw_rounds = rounds
        return self.n * int(eff.sum())

    def view(self) -> Tuple[jnp.ndarray, int]:
        """(dwarm, free_rounds) for a search call, width-padded with zeros.

        The device array is memoised: repeat calls are free, and growth
        within the current padded width patches only the new column slice
        on device (``.at[].set``) instead of re-uploading the whole cache —
        a full host→device ship happens only when the width itself steps
        to the next PAD_ROUNDS multiple."""
        wr = min(-(-max(self.hw_rounds, 1) // self.PAD_ROUNDS)
                 * self.PAD_ROUNDS, self.n_rounds_max)
        width = wr * self.B
        if self._view is None or self._view.shape[1] != width:
            dwarm = np.zeros((self.n, width), np.float32)
            dwarm[:, : self.hw_rounds * self.B] = self._cols
            self._view = jnp.asarray(dwarm)
            self._view_hw = self.hw_rounds
        elif self._view_hw < self.hw_rounds:
            lo, hi = self._view_hw * self.B, self.hw_rounds * self.B
            self._view = self._view.at[:, lo:hi].set(self._cols[:, lo:hi])
            self._view_hw = self.hw_rounds
        return self._view, self.hw_rounds


@functools.partial(jax.jit, static_argnames=("k",))
def _carry_delta(cols: jnp.ndarray, pidx: jnp.ndarray, pw: jnp.ndarray,
                 n_prefix: jnp.ndarray, d1o, d2o, ao, d1n, d2n, an,
                 sums: jnp.ndarray, sqsums: jnp.ndarray, *, k: int):
    """Re-validate carried SWAP arm statistics after an accepted swap.

    The carried Σg / Σg² (over the permutation prefix ``[0, n_prefix)``)
    were accumulated under the previous iteration's (d1, d2, assign).  The
    accepted swap changes ``g_{m,x}(y)`` only at reference points y whose
    (d1, d2, assign) moved — the virtual-arm decomposition
    ``g = base_x + 1[y∈C_m]·corr_x`` means each such point touches the
    shared base term plus at most its old and new cluster rows (the ≤2
    medoid rows invalidated by the swap); every other contribution is
    permutation-invariant and carried verbatim.  Both passes below read the
    PIC distance columns, so the whole update costs ZERO fresh distance
    evaluations.  Detection by exact comparison is safe: unchanged entries
    of ``medoid_cache`` are bit-identical recomputations.

    Returns (sums', sqsums', n_changed_positions).
    """
    width = cols.shape[1]
    in_prefix = (jnp.arange(width) < n_prefix).astype(jnp.float32)
    b1, b2, ba = d1o[pidx], d2o[pidx], ao[pidx]
    c1, c2, ca = d1n[pidx], d2n[pidx], an[pidx]
    changed = ((b1 != c1) | (b2 != c2) | (ba != ca)).astype(jnp.float32)
    w = pw * in_prefix * changed
    s_old, q_old = _swap_batch_stats(cols, b1, b2, ba, w, k)
    s_new, q_new = _swap_batch_stats(cols, c1, c2, ca, w, k)
    return (sums - s_old + s_new, sqsums - q_old + q_new,
            jnp.sum(w).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

# Every solver in the repo now emits the unified FitReport; the old name
# remains importable as a thin alias.
FitResult = FitReport


class BanditPAM:
    """k-medoids via adaptive sampling; same medoids as PAM w.h.p."""

    def __init__(self, k: int, metric: str = "l2", batch_size: int = 100,
                 delta: Optional[float] = None, max_swaps: Optional[int] = None,
                 seed: int = 0, sampling: str = "permutation",
                 baseline: str = "none", swap_early_stop: bool = False,
                 cache_cols: int = 0, reuse: str = "none"):
        if reuse not in ("none", "pic"):
            raise ValueError(f"unknown reuse mode {reuse!r}")
        if reuse == "pic" and sampling != "permutation":
            raise ValueError('reuse="pic" requires sampling="permutation" '
                             "(the cache is keyed by a fixed permutation)")
        self.k = int(k)
        self.metric = metric
        self.batch_size = int(batch_size)
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed
        self.sampling = sampling
        self.baseline = baseline
        self.swap_early_stop = swap_early_stop
        self.cache_cols = cache_cols
        self.reuse = reuse

    def _cache_view(self):
        """(perm, dwarm, free_rounds) for the next search under either
        cache regime (PIC lazily-grown vs upfront warm block vs none)."""
        if self._pic is not None:
            dwarm, free_rounds = self._pic.view()
            return self._pic.perm, dwarm, free_rounds
        return self._perm, self._dwarm, self._free_rounds

    # -- BUILD ----------------------------------------------------------
    def _make_cache(self, data: jnp.ndarray, key: jax.Array, res: FitResult):
        """Paper App 2.2: one fixed reference permutation for every search
        + a warm block of its first C distance columns, paid once."""
        n = data.shape[0]
        if self.cache_cols <= 0 or self.sampling != "permutation":
            return None, None, 0
        c = (min(self.cache_cols, n) // self.batch_size) * self.batch_size
        if c <= 0:
            return None, None, 0
        perm = jax.random.permutation(key, n).astype(jnp.int32)
        dwarm = get_metric(self.metric)(data, data[perm[:c]])
        res.evals_by_phase["cache_warm"] = n * c
        return perm, dwarm, c // self.batch_size

    def _build(self, data: jnp.ndarray, key: jax.Array, res: FitResult):
        n = data.shape[0]
        dist = get_metric(self.metric)
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        dnear = jnp.full((n,), jnp.inf, jnp.float32)
        med_mask = jnp.zeros((n,), jnp.bool_)
        medoids: List[int] = []
        build_evals = 0
        build_cached = 0
        for _ in range(self.k):
            key, sub = jax.random.split(key)
            perm, dwarm, free_rounds = self._cache_view()
            sr = _build_search(data, dnear, med_mask, sub, metric=self.metric,
                               batch_size=self.batch_size, delta=delta,
                               sampling=self.sampling, baseline=self.baseline,
                               perm=perm, dwarm=dwarm, free_rounds=free_rounds)
            m = int(sr.best)
            medoids.append(m)
            med_mask = med_mask.at[m].set(True)
            drow = dist(data[m][None, :], data)[0]
            dnear = jnp.minimum(dnear, drow)
            if self._pic is not None:
                # Fresh cost = the columns newly materialised into the PIC
                # cache (full columns, so later searches get them free);
                # warm rounds are tallied separately as cached reads.
                build_evals += self._pic.ensure(int(sr.rounds)) + n
                build_cached += int(sr.n_evals_cached)
            else:
                build_evals += int(sr.n_evals) + n
            res.build_rounds.append(int(sr.rounds))
        res.evals_by_phase["build"] = build_evals
        if self._pic is not None:
            res.evals_by_phase["build_cached"] = build_cached
        return jnp.asarray(medoids, jnp.int32), med_mask, key

    # -- SWAP -----------------------------------------------------------
    def _swap(self, data: jnp.ndarray, medoids: jnp.ndarray,
              med_mask: jnp.ndarray, key: jax.Array, res: FitResult):
        n = data.shape[0]
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * self.k * n)
        swap_evals = 0
        swap_cached = 0
        loss = float(total_loss(data, medoids, metric=self.metric))
        converged = False
        carry = None  # (sums, sqsums, rounds, d1, d2, assign) of the last search
        for _ in range(self.max_swaps):
            d1, d2, assign = medoid_cache(data, medoids, metric=self.metric)
            swap_evals += n * self.k
            init_sums = init_sqsums = None
            init_rounds = 0
            perm, dwarm, free_rounds = self._cache_view()
            if carry is not None:
                # BanditPAM++ PIC: the previous search's per-arm moments stay
                # valid for every arm whose g is unchanged; _carry_delta
                # repairs only the contributions of reference points hit by
                # the accepted swap, from cached columns (zero fresh evals).
                c_sums, c_sq, c_rounds, d1o, d2o, ao = carry
                width = dwarm.shape[1]
                init_sums, init_sqsums, n_changed = _carry_delta(
                    dwarm, self._pic.perm_idx[:width], self._pic.perm_w[:width],
                    jnp.int32(c_rounds * self.batch_size), d1o, d2o, ao,
                    d1, d2, assign, c_sums, c_sq, k=self.k)
                swap_cached += n * int(n_changed)
                init_rounds = c_rounds
            key, sub = jax.random.split(key)
            sr = _swap_search(data, d1, d2, assign, med_mask, sub,
                              metric=self.metric, batch_size=self.batch_size,
                              delta=delta, k=self.k, sampling=self.sampling,
                              baseline=self.baseline,
                              early_stop=self.swap_early_stop,
                              perm=perm, dwarm=dwarm, free_rounds=free_rounds,
                              init_sums=init_sums, init_sqsums=init_sqsums,
                              init_rounds=jnp.int32(init_rounds))
            if self._pic is not None:
                swap_evals += self._pic.ensure(int(sr.rounds))
                swap_cached += int(sr.n_evals_cached)
                carry = (sr.sums, sr.sqsums, int(sr.rounds), d1, d2, assign)
            else:
                swap_evals += int(sr.n_evals)
            res.swap_exact_fallbacks += int(sr.used_exact)
            m_idx, x_idx = divmod(int(sr.best), n)
            cand = medoids.at[m_idx].set(x_idx)
            new_loss = float(total_loss(data, cand, metric=self.metric))
            swap_evals += n * self.k
            if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
                old = int(medoids[m_idx])
                medoids = cand
                med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
                res.swap_history.append((old, x_idx, new_loss))
                loss = new_loss
            else:
                converged = True
                break
        res.evals_by_phase["swap"] = swap_evals
        if self._pic is not None:
            res.evals_by_phase["swap_cached"] = swap_cached
        return medoids, loss, converged

    # -- public ----------------------------------------------------------
    def fit(self, data) -> FitResult:
        data = jnp.asarray(data, jnp.float32)
        if data.shape[0] <= self.k:
            raise ValueError("need n > k")
        key = jax.random.PRNGKey(self.seed)
        res = FitResult(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0)
        key, ckey = jax.random.split(key)
        if self.reuse == "pic":
            self._perm, self._dwarm, self._free_rounds = None, None, 0
            perm = jax.random.permutation(ckey, data.shape[0]).astype(jnp.int32)
            self._pic = _PicCache(data, perm, self.batch_size, self.metric)
            if self.cache_cols > 0:
                # optional upfront warm block, same semantics as reuse="none"
                warm = min(self.cache_cols, data.shape[0]) // self.batch_size
                res.evals_by_phase["cache_warm"] = self._pic.ensure(warm)
        else:
            self._pic = None
            self._perm, self._dwarm, self._free_rounds = self._make_cache(
                data, ckey, res)
        medoids, med_mask, key = self._build(data, key, res)
        medoids, loss, converged = self._swap(data, medoids, med_mask, key, res)
        res.medoids = np.asarray(medoids)
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = sum(v for ph, v in res.evals_by_phase.items()
                                 if not ph.endswith("_cached"))
        res.cached_evals = sum(v for ph, v in res.evals_by_phase.items()
                               if ph.endswith("_cached"))
        return res

    def fit_predict(self, data) -> Tuple[FitResult, np.ndarray]:
        warnings.warn(
            "BanditPAM.fit_predict returns a (FitReport, labels) tuple, which "
            "diverges from the sklearn convention; use "
            "repro.api.KMedoids(...).fit_predict for labels-only",
            FutureWarning, stacklevel=2)
        res = self.fit(data)
        data = jnp.asarray(data, jnp.float32)
        _, _, assign = medoid_cache(data, jnp.asarray(res.medoids),
                                    metric=self.metric)
        return res, np.asarray(assign)
