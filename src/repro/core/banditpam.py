"""BanditPAM: the paper's algorithm — BUILD + SWAP driven by Algorithm 1.

Faithful to the paper:

* BUILD (Eq. 6): arms = candidate points, ``g_x(y) = (d(x,y) − d_near(y)) ∧ 0``
  against the cached nearest-medoid distance; the first assignment uses
  ``g_x(y) = d(x,y)`` (Eq. 4 with an empty medoid set).
* SWAP (Eq. 7 + Appendix Eq. 12 / FastPAM1): arms = (medoid m, candidate x)
  pairs.  One distance ``d(x,y)`` serves all k arms ``(·, x)`` via the cached
  ``d₁, d₂`` and cluster assignment — evaluated as a base term plus a
  one-hot matmul correction (``engine._swap_batch_stats`` / the fused
  Pallas kernels), which never materialises a ``[k, n, B]`` tensor.
* σ_x re-estimated from the first batch of every Algorithm 1 call (Eq. 11,
  Appendix 1.2), B = 100, δ = 1/(1000·|S_tar|) by default (§3.2).
* SWAP iterations repeat until the chosen swap no longer improves the exact
  loss, with a hard cap T (paper §4 Remark 1).

Device-resident driver (docs/design.md hardware adaptation #5): the
g-statistics are computed through a pluggable :class:`~repro.core.engine`
``StatsBackend`` (``backend="auto"/"pallas"/"jnp"``), and the control flow
is structured so the hot path never leaves the accelerator:

* BUILD is ONE jit dispatch: a ``lax.fori_loop`` over the k medoid
  selections with the ``adaptive_search`` while-loop inside and
  ``d_near`` / the medoid mask as loop carry — no per-medoid host sync,
  no per-medoid retrace.
* Each SWAP iteration is ONE fused device step (medoid-cache refresh +
  carried-moment repair + bandit search + candidate loss); only the
  accept/converge decision reads a scalar back on host.
* The BanditPAM++ PIC cache is a bounded-width device ring
  (``repro.core.pic_cache``, ``cache_width`` columns ≈ a few dozen
  round-batches by default — O(n·width) memory with width ≪ n) threaded
  through the search carry with stats-side write-through: each fresh
  distance column is stored by the very round that computes it, and the
  host never touches a distance column.  When a fit outgrows the ring,
  the oldest round's slots are recycled and any later read of a recycled
  round falls back to fresh recomputation — bit-identical blocks, so
  medoids/loss are unchanged and only the fresh/cached split moves.

``fused=False`` keeps the host-orchestrated driver (one dispatch per
medoid / per swap sub-step, host syncs between) built from the same
pieces — the in-run baseline ``benchmarks/core_bench.py`` measures the
fusion against.

Distance-evaluation accounting (the paper's headline metric) is algorithmic
and backend-independent: each bandit round pays ``#active-arms × B`` in
BUILD and ``#distinct-active-candidates × B`` in SWAP (FastPAM1 sharing),
cache (re)computation pays ``n·k``, and the d_near update after each BUILD
assignment pays ``n`` — exactly the ledger of the reference implementation.

Beyond the paper, ``BanditPAM(reuse="pic")`` enables the BanditPAM++
(Tiwari et al. 2023) SWAP-phase reuse engine:

* **PIC** — every search samples the SAME fixed reference permutation, and
  the distance columns it consumes are materialised once (write-through
  into the device cache); later searches replay those rounds for free.
* **Virtual arms** — per-arm Σg / Σg² from swap iteration *t* are carried
  into iteration *t+1* and repaired only where the accepted swap moved a
  reference point's (d1, d2, assign); per changed point that touches the
  shared base term plus at most the point's old and new cluster rows
  (``_carry_delta``).  A search seeded this way usually resolves its argmin
  from the carried exact prefix without sampling at all.

Under ``reuse="pic"`` the ledger splits into fresh vs cached: fresh pays
``n`` per newly materialised cache column (plus the ``n·k`` cache/loss
terms), cached tallies carried-prefix replays, warm rounds and delta
repairs.  ``reuse="none"`` reproduces the original ledger exactly.
"""

from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import SearchResult, adaptive_search
from .distances import get_metric
from .engine import (_EXACT_CHUNK, _build_g, _ref_chunks, _swap_batch_stats,
                     _swap_terms, FitContext, cache_read_or_write,
                     counted_dispatch, exact_build_means, exact_swap_means,
                     get_stats_backend, host_read, host_stage, medoid_cache,
                     observe_tiles, resolve_stats_backend,
                     resolve_tile_config, stream_columns, total_loss)
from .pic_cache import (PicCache, carry_valid, fresh_positions, make_cache,
                        resolve_batch_cache_rounds, resolve_cache_rounds)
from .report import BatchFitReport, FitReport

__all__ = ["BanditPAM", "BatchFitReport", "FitResult", "medoid_cache",
           "total_loss"]

# Re-exported for the siblings (pam, distributed) and external callers that
# historically imported the shared math from here; it now lives in engine.
_ = (SearchResult, _EXACT_CHUNK, _build_g, _ref_chunks, _swap_batch_stats,
     _swap_terms)


# ---------------------------------------------------------------------------
# BanditPAM++ carried-moment repair (virtual arms)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "backend"))
def _carry_delta(cols: jnp.ndarray, pidx: jnp.ndarray, pw: jnp.ndarray,
                 n_prefix: jnp.ndarray, d1o, d2o, ao, d1n, d2n, an,
                 sums: jnp.ndarray, sqsums: jnp.ndarray, *, k: int,
                 backend: str):
    """Re-validate carried SWAP arm statistics after an accepted swap.

    The carried Σg / Σg² (over the permutation prefix ``[0, n_prefix)``)
    were accumulated under the previous iteration's (d1, d2, assign).  The
    accepted swap changes ``g_{m,x}(y)`` only at reference points y whose
    (d1, d2, assign) moved — the virtual-arm decomposition
    ``g = base_x + 1[y∈C_m]·corr_x`` means each such point touches the
    shared base term plus at most its old and new cluster rows (the ≤2
    medoid rows invalidated by the swap); every other contribution is
    permutation-invariant and carried verbatim.  Both passes below read the
    PIC distance columns through the stats backend's cache-served path —
    on Pallas that is the ``swap_g_stats_cached`` kernel over the full
    capped cache width — so the whole update costs ZERO fresh distance
    evaluations.  Detection by exact comparison is safe: unchanged entries
    of ``medoid_cache`` are bit-identical recomputations.

    ``cols`` is the capped PIC ring ``[n, W·B]``; the caller guarantees
    ``n_prefix ≤ W·B`` (and passes 0 once recycling has invalidated the
    prefix — see ``pic_cache.carry_valid``), under which ring slots are
    the identity mapping of permutation positions.

    Returns (sums', sqsums', n_changed_positions).
    """
    be = get_stats_backend(backend)
    width = cols.shape[1]
    in_prefix = (jnp.arange(width) < n_prefix).astype(jnp.float32)
    b1, b2, ba = d1o[pidx], d2o[pidx], ao[pidx]
    c1, c2, ca = d1n[pidx], d2n[pidx], an[pidx]
    changed = ((b1 != c1) | (b2 != c2) | (ba != ca)).astype(jnp.float32)
    w = pw * in_prefix * changed
    s_old, q_old, _ = be.swap_stats_from_d(cols, b1, b2, ba, w, k, None)
    s_new, q_new, _ = be.swap_stats_from_d(cols, c1, c2, ca, w, k, None)
    return (sums - s_old + s_new, sqsums - q_old + q_new,
            jnp.sum(w).astype(jnp.int32))


# ---------------------------------------------------------------------------
# BUILD
# ---------------------------------------------------------------------------

def _build_step(data, dnear, med_mask, key, cache, dwarm, perm,
                perm_idx=None, perm_w=None, valid=None, n_valid=None,
                log_term=None, *,
                backend: str, metric: str, batch_size: int, delta: float,
                sampling: str, baseline: str, mode: str, free_rounds: int = 0
                ) -> SearchResult:
    """One BUILD medoid selection (one Algorithm 1 call).

    ``mode`` is the cache regime (see :class:`FitContext`).  Under
    ``"pic"`` the bounded :class:`PicCache` ring rides the search carry
    with write-through and comes back in ``SearchResult.aux``.

    The trailing optional args are the batched multi-fit lane state
    (``fit_batch``): an explicit pre-tiled reference layout
    (``perm_idx``/``perm_w`` — what the single-fit search would derive
    from ``key``/``perm`` at trace time, passed as data because the
    logical n is ragged), the row-validity mask (pad rows may never
    become medoids), and the traced per-fit budget/δ
    (``n_valid``/``log_term``).  All default to None → the historical
    single-fit trace, bit-identically.
    """
    n = data.shape[0]
    be = get_stats_backend(backend)
    B = batch_size
    # baseline="none" never reads the leader cross-sum; lead=None lets the
    # backends skip the leader-row work entirely (static at trace time).
    ld = (lambda lead: lead) if baseline == "leader" else (lambda lead: None)

    if mode == "pic":
        def stats_fn(ref_idx, w, lead, rnd, aux):
            dxy, aux = cache_read_or_write(
                be, data, ref_idx, metric=metric, batch_size=B, rnd=rnd,
                b_eff=jnp.sum(w).astype(jnp.int32), cache=aux)
            s, q, c = be.build_stats_from_d(dxy, dnear[ref_idx], w, ld(lead))
            return s, q, c, aux

        aux_init = cache
        free = cache.hw
        free_lo = jnp.maximum(cache.hw - cache.cols.shape[1] // B, 0)
    elif mode == "warm":
        def stats_fn(ref_idx, w, lead, rnd):
            # paper App 2.2 cache: warm rounds read precomputed distance
            # columns (same fixed permutation across every search call)
            return jax.lax.cond(
                rnd < free_rounds,
                lambda _: be.build_stats_from_d(
                    jax.lax.dynamic_slice_in_dim(dwarm, rnd * B, B, 1),
                    dnear[ref_idx], w, ld(lead)),
                lambda _: be.build_stats(data, ref_idx, dnear[ref_idx], w,
                                         ld(lead), metric=metric),
                None)

        aux_init = None
        free = free_rounds
        free_lo = 0
    else:
        def stats_fn(ref_idx, w, lead, rnd):
            return be.build_stats(data, ref_idx, dnear[ref_idx], w,
                                  ld(lead), metric=metric)

        aux_init = None
        free = 0
        free_lo = 0

    def exact_fn():
        return exact_build_means(be, data, dnear, metric=metric)

    active0 = jnp.logical_not(med_mask)
    if valid is not None:
        active0 = jnp.logical_and(active0, valid)
    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=n, n_ref=n, batch_size=B, delta=delta,
                           active_init=active0,
                           sampling=sampling, baseline=baseline, perm=perm,
                           perm_idx=perm_idx, perm_w=perm_w,
                           free_rounds=free, free_lo=free_lo,
                           aux_init=aux_init, n_ref_eff=n_valid,
                           log_term=log_term)


_build_step_jit = jax.jit(
    _build_step, static_argnames=("backend", "metric", "batch_size", "delta",
                                  "sampling", "baseline", "mode",
                                  "free_rounds"))


# ``donate_argnums=(2,)`` donates the PIC ring: the caller replaces
# ``ctx.cache`` with the returned buffers and never touches the old ones,
# so the O(n·width) cols block aliases in place instead of doubling the
# fit's resident footprint (graphcheck GRC005 pins the aliasing in the
# lowered program).  Under ``mode="none"`` the cache is a leafless None
# and the donation is a no-op.
@functools.partial(jax.jit,
                   static_argnames=("backend", "metric", "batch_size",
                                    "delta", "sampling", "baseline", "k",
                                    "mode", "free_rounds"),
                   donate_argnums=(2,))
def _build_fused(data, subkeys, cache, dwarm, perm, spidx=None, spw=None,
                 valid=None, n_valid=None, log_term=None, *, backend: str,
                 metric: str, batch_size: int, delta: float, sampling: str,
                 baseline: str, k: int, mode: str, free_rounds: int):
    """The whole BUILD phase as ONE jit: ``fori_loop`` over the k medoid
    selections, with d_near / the medoid mask / the bounded device PIC
    cache as loop carry.  Returns per-step rounds and the fresh/cached
    ledger entries so the host never syncs mid-phase.

    ``spidx``/``spw`` (batched multi-fit lanes): explicit pre-tiled
    reference layouts — ``[k, R·B]`` for per-selection permutations
    (``reuse="none"``, one per search key) or ``[R·B]`` for the one fixed
    PIC permutation shared by every search."""
    n = data.shape[0]
    B = batch_size
    dist = get_metric(metric)
    pic = mode == "pic"

    def body(i, c):
        dnear, med_mask, medoids, cc, rounds_a, evals_a, cached_a = c
        if spidx is None:
            spidx_i = None
        else:
            spidx_i = spidx if spidx.ndim == 1 else spidx[i]
        sr = _build_step(data, dnear, med_mask, subkeys[i], cc, dwarm, perm,
                         spidx_i, spw, valid, n_valid, log_term,
                         backend=backend, metric=metric, batch_size=B,
                         delta=delta, sampling=sampling, baseline=baseline,
                         mode=mode, free_rounds=free_rounds)
        m = sr.best
        medoids = medoids.at[i].set(m)
        med_mask = med_mask.at[m].set(True)
        dnear = jnp.minimum(dnear, dist(data[m][None, :], data)[0])
        if pic:
            # Fresh cost = n per column this search computed
            # (materialisations serve every later search, recycled-slot
            # replays are paid again); the position COUNT is stored and
            # the host multiplies by n (a device-side uint32 product
            # would wrap at large n).  Warm rounds are tallied
            # separately as cached reads.
            cc2 = sr.aux
            fresh = fresh_positions(cc, cc2)
            cached_a = cached_a.at[i].set(sr.n_evals_cached)
            cc = cc2
        else:
            fresh = sr.n_evals
        evals_a = evals_a.at[i].set(fresh)
        rounds_a = rounds_a.at[i].set(sr.rounds)
        return (dnear, med_mask, medoids, cc, rounds_a, evals_a, cached_a)

    init = (jnp.full((n,), jnp.inf, jnp.float32),
            jnp.zeros((n,), jnp.bool_),
            jnp.zeros((k,), jnp.int32),
            cache,
            jnp.zeros((k,), jnp.int32),
            jnp.zeros((k,), jnp.uint32),
            jnp.zeros((k,), jnp.uint32))
    return jax.lax.fori_loop(0, k, body, init)


# ---------------------------------------------------------------------------
# SWAP (FastPAM1 fused form)
# ---------------------------------------------------------------------------

def _swap_search(data, d1, d2, assign, med_mask, key, cache, dwarm, perm,
                 init_sums, init_sqsums, init_rounds, s_pidx=None, s_pw=None,
                 valid=None, n_valid=None, log_term=None, *, backend: str,
                 metric: str, batch_size: int, delta: float, k: int,
                 sampling: str, baseline: str, early_stop: bool, mode: str,
                 free_rounds: int = 0) -> SearchResult:
    """One SWAP best-arm search over the (medoid, candidate) arm set.

    The trailing optional args are the batched multi-fit lane state (see
    ``_build_step``); ``s_pidx``/``s_pw`` is this search's pre-tiled
    reference layout."""
    n = data.shape[0]
    be = get_stats_backend(backend)
    B = batch_size
    ld = (lambda lead: lead) if baseline == "leader" else (lambda lead: None)

    if mode == "pic":
        def stats_fn(ref_idx, w, lead, rnd, aux):
            dxy, aux = cache_read_or_write(
                be, data, ref_idx, metric=metric, batch_size=B, rnd=rnd,
                b_eff=jnp.sum(w).astype(jnp.int32), cache=aux)
            s, q, c = be.swap_stats_from_d(dxy, d1[ref_idx], d2[ref_idx],
                                           assign[ref_idx], w, k, ld(lead))
            return s, q, c, aux

        aux_init = cache
        free = cache.hw
        free_lo = jnp.maximum(cache.hw - cache.cols.shape[1] // B, 0)
    elif mode == "warm":
        def stats_fn(ref_idx, w, lead, rnd):
            return jax.lax.cond(
                rnd < free_rounds,
                lambda _: be.swap_stats_from_d(
                    jax.lax.dynamic_slice_in_dim(dwarm, rnd * B, B, 1),
                    d1[ref_idx], d2[ref_idx], assign[ref_idx], w, k,
                    ld(lead)),
                lambda _: be.swap_stats(data, ref_idx, d1[ref_idx],
                                        d2[ref_idx], assign[ref_idx], w, k,
                                        ld(lead), metric=metric),
                None)

        aux_init = None
        free = free_rounds
        free_lo = 0
    else:
        def stats_fn(ref_idx, w, lead, rnd):
            return be.swap_stats(data, ref_idx, d1[ref_idx], d2[ref_idx],
                                 assign[ref_idx], w, k, ld(lead),
                                 metric=metric)

        aux_init = None
        free = 0
        free_lo = 0

    def exact_fn():
        return exact_swap_means(be, data, d1, d2, assign, k, metric=metric)

    # Candidates that are already medoids (or pad rows of a batched
    # ragged fit) are not valid swap targets.
    cand_ok = jnp.logical_not(med_mask)
    if valid is not None:
        cand_ok = jnp.logical_and(cand_ok, valid)
    active0 = jnp.tile(cand_ok[None, :], (k, 1)).reshape(-1)

    def count_fn(active):
        # FastPAM1: one distance per (x, y) pair serves all k arms (·, x).
        any_x = jnp.any(active.reshape(k, n), axis=0)
        return jnp.sum(any_x.astype(jnp.uint32))

    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=k * n, n_ref=n, batch_size=B, delta=delta,
                           active_init=active0, count_fn=count_fn,
                           sampling=sampling, baseline=baseline,
                           stop_when_positive=early_stop, perm=perm,
                           perm_idx=s_pidx, perm_w=s_pw,
                           free_rounds=free, free_lo=free_lo,
                           init_sums=init_sums, init_sqsums=init_sqsums,
                           init_rounds=init_rounds, aux_init=aux_init,
                           n_ref_eff=n_valid, log_term=log_term)


_swap_search_jit = jax.jit(
    _swap_search, static_argnames=("backend", "metric", "batch_size",
                                   "delta", "k", "sampling", "baseline",
                                   "early_stop", "mode", "free_rounds"))


def _swap_iter(data, medoids, med_mask, key, cache, dwarm, perm, perm_idx,
               perm_w, carry, prev_loss, s_pidx=None, s_pw=None, valid=None,
               n_valid=None, log_term=None, *, backend: str, metric: str,
               batch_size: int, delta: float, k: int, sampling: str,
               baseline: str, early_stop: bool, mode: str, free_rounds: int):
    """One SWAP iteration as a single fused device step: medoid-cache
    refresh + carried-moment repair (``_carry_delta``) + bandit search +
    candidate loss + the accept decision against ``prev_loss``.  Only the
    accept/converge flag (one scalar read) is left to the host.

    The accept comparison runs ON DEVICE in f32 (it used to be a host
    f64 compare): the batched multi-fit driver must decide inside its
    per-lane ``while_loop``, and keeping one definition for both paths
    is what makes ``fit_batch`` ≡ loop-of-``fit`` hold bit-for-bit at
    accept margins.  The trailing optional args are the batched lane
    state (see ``_build_step``)."""
    n = data.shape[0]
    B = batch_size
    d1, d2, assign = medoid_cache(data, medoids, metric=metric)
    n_changed = jnp.int32(0)
    init_sums = init_sqsums = None
    init_rounds = 0
    if carry is not None:
        # BanditPAM++ PIC: the previous search's per-arm moments stay
        # valid for every arm whose g is unchanged; _carry_delta repairs
        # only the contributions of reference points hit by the accepted
        # swap, from cached columns (zero fresh evals).  Once the ring
        # has recycled a round the carried prefix is no longer resident,
        # so the repair is skipped entirely (lax.cond — no wasted
        # O(n·W·B) pass) and the search starts cold — exact either way,
        # only the fresh/cached split moves.
        c_sums, c_sq, c_rounds, d1o, d2o, ao = carry
        resident = carry_valid(cache, B)

        def repair(_):
            return _carry_delta(cache.cols, perm_idx, perm_w, c_rounds * B,
                                d1o, d2o, ao, d1, d2, assign, c_sums, c_sq,
                                k=k, backend=backend)

        def cold(_):
            return (jnp.zeros_like(c_sums), jnp.zeros_like(c_sq),
                    jnp.int32(0))

        init_sums, init_sqsums, n_changed = jax.lax.cond(
            resident, repair, cold, None)
        init_rounds = jnp.where(resident, c_rounds, 0)
    sr = _swap_search(data, d1, d2, assign, med_mask, key, cache, dwarm,
                      perm, init_sums, init_sqsums, init_rounds,
                      s_pidx, s_pw, valid, n_valid, log_term,
                      backend=backend, metric=metric, batch_size=B,
                      delta=delta, k=k, sampling=sampling, baseline=baseline,
                      early_stop=early_stop, mode=mode,
                      free_rounds=free_rounds)
    if mode == "pic":
        cache2 = sr.aux
        fresh = fresh_positions(cache, cache2)
    else:
        cache2 = cache
        fresh = sr.n_evals
    m_idx = sr.best // n
    x_idx = sr.best % n
    cand = medoids.at[m_idx].set(x_idx)
    new_loss = total_loss(data, cand, metric=metric, w=valid)
    # The one accept rule (f32, on device) shared by the single-fit
    # driver and every fit_batch lane.
    accept = new_loss < prev_loss - 1e-7 * jnp.maximum(1.0,
                                                       jnp.abs(prev_loss))
    new_carry = (sr.sums, sr.sqsums, sr.rounds, d1, d2, assign)
    # The displaced medoid and the accepted-state mask are produced IN
    # TRACE so the host driver never does eager index arithmetic on
    # device arrays (which would be implicit transfers under the
    # transfer guard); the driver just selects cand/new_mask on accept.
    old_med = medoids[m_idx]
    new_mask = med_mask.at[old_med].set(False).at[x_idx].set(True)
    # fresh is a POSITION count and n_changed a point count under "pic";
    # the host driver multiplies both by n (uint32-safe).
    return (sr.best, new_loss, cand, new_mask, old_med, new_carry, cache2,
            fresh, sr.n_evals_cached, n_changed, sr.used_exact, accept)


# Donations: the PIC ring (arg 4) and the carried swap moments (arg 9)
# are consumed by each iteration and replaced by its outputs — the driver
# reassigns ``ctx.cache``/``carry`` and never reads the old buffers, so
# both alias in place.  First iterations pass ``carry=None`` (leafless,
# donation no-op) and trace separately from the steady state anyway.
_swap_iter_jit = jax.jit(
    _swap_iter, static_argnames=("backend", "metric", "batch_size", "delta",
                                 "k", "sampling", "baseline", "early_stop",
                                 "mode", "free_rounds"),
    donate_argnums=(4, 9))


# ---------------------------------------------------------------------------
# Batched multi-fit phase drivers (fit_batch)
# ---------------------------------------------------------------------------
#
# One jit per phase over a [batch] axis of independent padded fits.  The
# batch axis is lowered with ``lax.map`` (a scan over lanes), NOT vmap:
# vmap rewrites the per-lane GEMMs into batched contractions whose f32
# accumulation order differs from the single-fit trace (~1e-3 drift in
# d_near on CPU), which breaks the bit-parity invariant the differential
# harness pins.  Under lax.map every lane executes the same per-fit HLO
# as the single-fit jit, so medoids, losses, AND the fresh/cached ledger
# reproduce the loop of single fits exactly — while the whole batch is
# still one dispatch, one compilation, and no per-fit host sync.

# NOT donated: the stacked [B, n, width] ring rides the ``lax.map`` scan
# as per-lane xs/ys, and XLA materialises scan outputs by dynamic-update-
# slice into a fresh stacked buffer — the input ring cannot alias it
# (donating anyway just emits "donated buffers were not usable").  The
# single-fit drivers, whose cache is a plain argument/result pair, DO
# donate; graphcheck GRC005 pins that split (docs/design.md #10).
@functools.partial(jax.jit,
                   static_argnames=("backend", "metric", "batch_size",
                                    "delta", "sampling", "baseline", "k",
                                    "mode", "free_rounds"))
def _build_batch(data, subkeys, cache, spidx, spw, valid, n_valid, log_term,
                 *, backend: str, metric: str, batch_size: int, delta,
                 sampling: str, baseline: str, k: int, mode: str,
                 free_rounds: int):
    """BUILD for a [batch] of padded fits: ONE jit, ``lax.map`` over the
    per-fit ``_build_fused`` lanes.  Every array input carries a leading
    batch axis (``cache`` is a stacked :class:`PicCache` pytree or None).
    Returns stacked (med_mask, medoids, cache, rounds, fresh, cached)."""

    def lane(xs):
        data_i, keys_i, cache_i, spidx_i, spw_i, valid_i, nv_i, lt_i = xs
        (dnear, med_mask, medoids, cc, rounds_a, evals_a,
         cached_a) = _build_fused(
             data_i, keys_i, cache_i, None, None, spidx_i, spw_i, valid_i,
             nv_i, lt_i, backend=backend, metric=metric,
             batch_size=batch_size, delta=delta, sampling=sampling,
             baseline=baseline, k=k, mode=mode, free_rounds=free_rounds)
        del dnear  # not needed post-BUILD; keep the lane output lean
        return med_mask, medoids, cc, rounds_a, evals_a, cached_a

    return jax.lax.map(
        lane, (data, subkeys, cache, spidx, spw, valid, n_valid, log_term))


@functools.partial(jax.jit,
                   static_argnames=("backend", "metric", "batch_size",
                                    "delta", "k", "sampling", "baseline",
                                    "early_stop", "mode", "free_rounds",
                                    "max_swaps"))
def _swap_batch(data, medoids, med_mask, subkeys, cache, pidx_c, pw_c,
                spidx, spw, valid, n_valid, log_term, *, backend: str,
                metric: str, batch_size: int, delta, k: int, sampling: str,
                baseline: str, early_stop: bool, mode: str, free_rounds: int,
                max_swaps: int):
    """The whole SWAP phase for a [batch] of padded fits as ONE jit: each
    ``lax.map`` lane runs its own accept-driven ``while_loop`` over up to
    ``max_swaps`` fused ``_swap_iter`` steps, with the accept decision on
    device (the same f32 rule the single-fit driver reads back).

    ``pidx_c``/``pw_c`` are the per-fit carry-repair layouts over the PIC
    ring width (``_carry_delta``); ``spidx`` the search layouts —
    ``[batch, T, R·B]`` per-iteration permutations (``reuse="none"``) or
    ``[batch, R·B]`` the one fixed PIC permutation.  The moment carry is
    seeded with ZEROS on the first iteration instead of the single-fit
    driver's ``carry=None`` cold start — equivalent by construction
    (``_carry_delta`` over an empty prefix is the identity on zeros, and
    ``adaptive_search`` re-derives σ from the first batch whenever
    ``n_used == 0``), which keeps the while-loop carry a fixed pytree.

    Per lane returns (medoids, loss, converged, iters, fresh, cached,
    n_changed, exact_fallbacks, old[T], new[T], loss[T], accept[T]) —
    everything the host needs to assemble per-fit FitReports without a
    mid-phase sync."""
    n = data.shape[1]
    kn = k * n
    T = max_swaps
    pic = mode == "pic"

    def lane(xs):
        (data_i, meds0, mask0, keys_i, cache_i, pidx_i, pw_i, spidx_i,
         spw_i, valid_i, nv_i, lt_i) = xs
        loss0 = total_loss(data_i, meds0, metric=metric, w=valid_i)
        if pic:
            carry0 = (jnp.zeros((kn,), jnp.float32),
                      jnp.zeros((kn,), jnp.float32), jnp.int32(0),
                      jnp.zeros((n,), jnp.float32),
                      jnp.zeros((n,), jnp.float32),
                      jnp.zeros((n,), jnp.int32))
        else:
            carry0 = None

        def cond(st):
            return jnp.logical_and(st[0] < T, jnp.logical_not(st[1]))

        def body(st):
            (t, done, meds, mask, loss, carry, cc, fresh_s, cached_s,
             nchg_s, exact_s, old_a, new_a, loss_a, acc_a) = st
            pidx_t = spidx_i if spidx_i.ndim == 1 else spidx_i[t]
            (best, new_loss, cand, new_mask, old, new_carry, cc2, fresh,
             cached, nchg, uexact, accept) = _swap_iter(
                 data_i, meds, mask, keys_i[t], cc, None, None, pidx_i,
                 pw_i, carry, loss, pidx_t, spw_i, valid_i, nv_i, lt_i,
                 backend=backend, metric=metric, batch_size=batch_size,
                 delta=delta, k=k, sampling=sampling, baseline=baseline,
                 early_stop=early_stop, mode=mode, free_rounds=free_rounds)
            x_idx = best % n
            meds2 = jnp.where(accept, cand, meds)
            mask2 = jnp.where(accept, new_mask, mask)
            return (t + 1, jnp.logical_not(accept), meds2, mask2,
                    jnp.where(accept, new_loss, loss),
                    new_carry if pic else None, cc2,
                    fresh_s + fresh, cached_s + cached, nchg_s + nchg,
                    exact_s + uexact.astype(jnp.int32),
                    old_a.at[t].set(old), new_a.at[t].set(x_idx),
                    loss_a.at[t].set(new_loss), acc_a.at[t].set(accept))

        st0 = (jnp.int32(0), jnp.bool_(False), meds0, mask0, loss0,
               carry0, cache_i, jnp.uint32(0), jnp.uint32(0),
               jnp.int32(0), jnp.int32(0),
               jnp.zeros((T,), jnp.int32), jnp.zeros((T,), jnp.int32),
               jnp.zeros((T,), jnp.float32), jnp.zeros((T,), jnp.bool_))
        stf = jax.lax.while_loop(cond, body, st0)
        return (stf[2], stf[4], stf[1], stf[0], stf[7], stf[8], stf[9],
                stf[10], stf[11], stf[12], stf[13], stf[14])

    return jax.lax.map(lane, (data, medoids, med_mask, subkeys, cache,
                              pidx_c, pw_c, spidx, spw, valid, n_valid,
                              log_term))


@functools.partial(jax.jit, static_argnames=("k", "T"))
def _batch_rng_chains(seeds, *, k: int, T: int):
    """Replicate every per-fit RNG chain in ONE dispatch: the exact
    PRNGKey/split sequence ``fit`` walks, vmapped over the seeds (split
    is an elementwise threefry application, so the vmapped bits are
    identical to the sequential ones).  Returns per-fit
    (ckey, build subkeys [k,2], swap subkeys [T,2], build perm-keys,
    swap perm-keys) — the perm-keys being the second-level
    ``split(sub)[1]`` that seeds each search's reference permutation."""

    def chain(seed):
        key = jax.random.PRNGKey(seed)
        key, ckey = jax.random.split(key)
        subs = []
        # tracecheck: ignore[TRC002] -- trace-constant unroll: k + T is a
        # static fit-shape bound, and the chain must replay the sequential
        # split order of the single-fit driver bit-for-bit.
        for _ in range(k + T):
            key, sub = jax.random.split(key)
            subs.append(sub)
        subs = jnp.stack(subs)
        # tracecheck: ignore[TRC005] -- vmap over key *derivation* only:
        # threefry split/fold_in are elementwise, so the vmapped bits equal
        # the sequential ones; no float reductions are vectorized here.
        pkeys = jax.vmap(lambda s: jax.random.split(s)[1])(subs)
        return ckey, subs[:k], subs[k:], pkeys[:k], pkeys[k:]

    # tracecheck: ignore[TRC005] -- same key-derivation exemption as above:
    # per-fit chains are integer threefry lanes, bit-stable under vmap.
    return jax.vmap(chain)(seeds)


@functools.partial(jax.jit, static_argnames=("n",))
def _batch_perms(keys, *, n: int):
    """[m, 2] keys -> [m, n] reference permutations, one dispatch (the
    vmapped sort matches ``jax.random.permutation`` row-for-row)."""
    # tracecheck: ignore[TRC005] -- vmapped argsort of per-row random bits:
    # each row's permutation matches jax.random.permutation(s, n) exactly
    # (locked by test_multifit bit-parity), no float accumulation involved.
    return jax.vmap(
        lambda s: jax.random.permutation(s, n).astype(jnp.int32))(keys)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

# Every solver in the repo now emits the unified FitReport; the old name
# remains importable as a thin alias.
FitResult = FitReport


class BanditPAM:
    """k-medoids via adaptive sampling; same medoids as PAM w.h.p.

    ``backend`` selects the g-statistics compute path
    (``repro.core.engine``): ``"auto"`` (kernels on accelerators, jnp on
    CPU), ``"pallas"``, ``"jnp"``, or any registered backend name.
    ``fused=False`` falls back to the host-orchestrated stepped driver
    (same math, one dispatch per sub-step) — the benchmark baseline.
    ``cache_width`` caps the ``reuse="pic"`` column ring (in reference
    columns, rounded down to round-batches; default a few dozen
    round-batches — see ``repro.core.pic_cache``).
    """

    def __init__(self, k: int, metric: str = "l2", batch_size: int = 100,
                 delta: Optional[float] = None, max_swaps: Optional[int] = None,
                 seed: int = 0, sampling: str = "permutation",
                 baseline: str = "none", swap_early_stop: bool = False,
                 cache_cols: int = 0, reuse: str = "none",
                 cache_width: Optional[int] = None,
                 backend: str = "auto", fused: bool = True):
        if reuse not in ("none", "pic"):
            raise ValueError(f"unknown reuse mode {reuse!r}")
        if reuse == "pic" and sampling != "permutation":
            raise ValueError('reuse="pic" requires sampling="permutation" '
                             "(the cache is keyed by a fixed permutation)")
        self.k = int(k)
        self.metric = metric
        self.batch_size = int(batch_size)
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed
        self.sampling = sampling
        self.baseline = baseline
        self.swap_early_stop = swap_early_stop
        self.cache_cols = cache_cols
        self.reuse = reuse
        # Width cap (in reference columns) of the PIC ring; None = auto
        # (a few dozen round-batches — O(n·width) memory, width ≪ n).
        self.cache_width = cache_width
        self.backend = backend
        self.fused = bool(fused)

    # -- per-fit context -------------------------------------------------
    def _make_context(self, data: jnp.ndarray, ckey: jax.Array, backend: str,
                      res: FitResult) -> FitContext:
        """Build the per-fit :class:`FitContext` (cache regime + buffers).

        All state lives on the context, never on the instance — ``fit`` is
        re-entrant and refitting the same estimator starts clean."""
        n = data.shape[0]
        be = get_stats_backend(backend)
        B = self.batch_size
        if self.reuse == "pic":
            perm = jax.random.permutation(ckey, n).astype(jnp.int32)
            n_rounds_max = -(-n // B)
            W = resolve_cache_rounds(n_rounds_max, B, self.cache_width)
            width = W * B
            perm_np = np.asarray(perm)
            # Prefix of adaptive_search's tiling at the capped width:
            # positions >= n are w=0 padding.
            perm_idx = jnp.asarray(np.tile(perm_np, -(-width // n))[:width])
            perm_w = jnp.asarray((np.arange(width) < n).astype(np.float32))
            cache = make_cache(n, B, W)
            if self.cache_cols > 0:
                # optional upfront warm block, same semantics as
                # reuse="none" (clamped to the ring capacity)
                warm = min(min(self.cache_cols, n) // B, W)
                if warm > 0:
                    cols = stream_columns(be, data,
                                          data[perm_idx[:warm * B]],
                                          metric=self.metric)
                    cache = PicCache(
                        cache.cols.at[:, :warm * B].set(cols),
                        jnp.int32(warm), jnp.uint32(warm * B))
                    res.evals_by_phase["cache_warm"] = n * warm * B
            return FitContext(mode="pic", backend=backend, perm=perm,
                              perm_idx=perm_idx, perm_w=perm_w, cache=cache)
        if self.cache_cols > 0 and self.sampling == "permutation":
            # Paper App 2.2: one fixed reference permutation for every
            # search + a warm block of its first C columns, paid once.
            c = (min(self.cache_cols, n) // B) * B
            if c > 0:
                perm = jax.random.permutation(ckey, n).astype(jnp.int32)
                dwarm = stream_columns(be, data, data[perm[:c]],
                                       metric=self.metric)
                res.evals_by_phase["cache_warm"] = n * c
                return FitContext(mode="warm", backend=backend, perm=perm,
                                  dwarm=dwarm, free_rounds=c // B)
        return FitContext(mode="none", backend=backend)

    # -- BUILD ----------------------------------------------------------
    def _build(self, data: jnp.ndarray, key: jax.Array, ctx: FitContext,
               res: FitResult):
        n = data.shape[0]
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        # One subkey per medoid selection, split exactly as the legacy
        # host loop did, so trajectories are seed-compatible.
        subs = []
        for _ in range(self.k):
            key, sub = jax.random.split(key)
            subs.append(sub)
        subkeys = jnp.stack(subs)
        kw = dict(backend=ctx.backend, metric=self.metric,
                  batch_size=self.batch_size, delta=delta,
                  sampling=self.sampling, baseline=self.baseline,
                  mode=ctx.mode, free_rounds=ctx.free_rounds)
        if self.fused:
            phase = counted_dispatch(_build_fused, res.dispatches_by_phase,
                                     "build")
            (dnear, med_mask, medoids, cache, rounds_a, evals_a,
             cached_a) = phase(data, subkeys, ctx.cache, ctx.dwarm,
                               ctx.perm, k=self.k, **kw)
            ctx.cache = cache
            # One explicit ledger read for the whole phase — the fused
            # BUILD stays a single dispatch plus a single device_get.
            rounds_a, evals_a, cached_a = host_read(
                (rounds_a, evals_a, cached_a))
        else:
            # Stepped baseline: one dispatch + one host sync per medoid.
            step = counted_dispatch(_build_step_jit,
                                    res.dispatches_by_phase, "build")
            dist = get_metric(self.metric)
            dnear = jnp.full((n,), jnp.inf, jnp.float32)
            med_mask = jnp.zeros((n,), jnp.bool_)
            cache = ctx.cache
            meds, rounds_a, evals_a, cached_a = [], [], [], []
            for i in range(self.k):
                sr = step(data, dnear, med_mask, subkeys[i],
                          cache, ctx.dwarm, ctx.perm, **kw)
                m = int(sr.best)
                meds.append(m)
                med_mask = med_mask.at[m].set(True)
                dnear = jnp.minimum(dnear, dist(data[m][None, :], data)[0])
                if ctx.mode == "pic":
                    cache2 = sr.aux
                    evals_a.append(int(fresh_positions(cache, cache2)))
                    cached_a.append(int(sr.n_evals_cached))
                    cache = cache2
                else:
                    evals_a.append(int(sr.n_evals))
                rounds_a.append(int(sr.rounds))
            medoids = jnp.asarray(meds, jnp.int32)
            ctx.cache = cache
        res.build_rounds.extend(
            int(r) for r in np.asarray(rounds_a, np.int64))
        # Under "pic" the per-step entries are fresh POSITION counts; the
        # n· multiply happens here on host ints (no uint32 wrap).
        scale = n if ctx.mode == "pic" else 1
        res.evals_by_phase["build"] = (
            scale * int(np.asarray(evals_a, np.int64).sum()) + n * self.k)
        if ctx.mode == "pic":
            res.evals_by_phase["build_cached"] = int(
                np.asarray(cached_a, np.int64).sum())
        return medoids, med_mask, key

    # -- SWAP -----------------------------------------------------------
    def _swap(self, data: jnp.ndarray, medoids: jnp.ndarray,
              med_mask: jnp.ndarray, key: jax.Array, ctx: FitContext,
              res: FitResult):
        n = data.shape[0]
        delta = (self.delta if self.delta is not None
                 else 1.0 / (1000.0 * self.k * n))
        swap_evals = 0
        swap_cached = 0
        # The running loss stays DEVICE-resident between iterations
        # (prev_loss_d feeds the next step's accept rule without a
        # host→device re-upload); the host mirror only serves the report.
        prev_loss_d = total_loss(data, medoids, metric=self.metric)
        loss = float(host_read(prev_loss_d))
        converged = False
        carry = None  # (sums, sqsums, rounds, d1, d2, assign) of last search
        kw = dict(backend=ctx.backend, metric=self.metric,
                  batch_size=self.batch_size, delta=delta, k=self.k,
                  sampling=self.sampling, baseline=self.baseline,
                  early_stop=self.swap_early_stop, mode=ctx.mode,
                  free_rounds=ctx.free_rounds)
        step = counted_dispatch(
            _swap_iter_jit if self.fused else self._swap_iter_stepped,
            res.dispatches_by_phase, "swap")
        for _ in range(self.max_swaps):
            key, sub = jax.random.split(key)
            (best, new_loss_d, cand, new_mask, old_med, new_carry, cache,
             fresh, cached, n_changed, used_exact, accept) = step(
                 data, medoids, med_mask, sub, ctx.cache, ctx.dwarm,
                 ctx.perm, ctx.perm_idx, ctx.perm_w, carry,
                 prev_loss_d, **kw)
            ctx.cache = cache
            # ONE explicit host read per iteration: every ledger counter,
            # the displaced medoid and the accept bit come back in a
            # single device_get, so the loop is one dispatch + one
            # sanctioned read under the transfer guard.
            (best_h, new_loss_h, old_h, fresh_h, cached_h, n_changed_h,
             used_exact_h, accept_h) = host_read(
                 (best, new_loss_d, old_med, fresh, cached, n_changed,
                  used_exact, accept))
            # Under "pic", fresh counts POSITIONS and n_changed counts
            # repaired points; the n· multiplies run on host ints so the
            # ledger cannot wrap at large n.
            scale = n if ctx.mode == "pic" else 1
            swap_evals += 2 * n * self.k + scale * int(fresh_h)
            swap_cached += int(cached_h) + n * int(n_changed_h)
            res.swap_exact_fallbacks += int(used_exact_h)
            if ctx.mode == "pic":
                carry = new_carry
            # The accept rule is evaluated ON DEVICE in f32 (inside
            # _swap_iter) — the same comparison every fit_batch lane
            # makes — so the two drivers cannot diverge at fp margins.
            # On accept the driver only SELECTS the in-trace results
            # (cand/new_mask); the running loss stays device-resident.
            if bool(accept_h):
                x_idx = int(best_h) % n
                medoids = cand
                med_mask = new_mask
                res.swap_history.append((int(old_h), x_idx,
                                         float(new_loss_h)))
                loss = float(new_loss_h)
                prev_loss_d = new_loss_d
            else:
                converged = True
                break
        res.evals_by_phase["swap"] = swap_evals
        if ctx.mode == "pic":
            res.evals_by_phase["swap_cached"] = swap_cached
        return medoids, loss, converged

    def _swap_iter_stepped(self, data, medoids, med_mask, key, cache, dwarm,
                           perm, perm_idx, perm_w, carry, prev_loss, *,
                           backend, metric, batch_size, delta, k, sampling,
                           baseline, early_stop, mode, free_rounds):
        """Host-orchestrated SWAP iteration (benchmark baseline): the same
        sub-steps as ``_swap_iter`` but as separate dispatches with host
        round-trips between — the pre-refactor driver architecture."""
        n = data.shape[0]
        B = batch_size
        d1, d2, assign = medoid_cache(data, medoids, metric=metric)
        jax.block_until_ready(d1)
        init_sums = init_sqsums = None
        init_rounds = 0
        n_changed = 0
        if carry is not None:
            c_sums, c_sq, c_rounds, d1o, d2o, ao = carry
            if bool(carry_valid(cache, B)):
                # Host branch of the fused driver's lax.cond: the repair
                # only runs while the carried prefix is ring-resident.
                init_sums, init_sqsums, nc = _carry_delta(
                    cache.cols, perm_idx, perm_w, c_rounds * B,
                    d1o, d2o, ao, d1, d2, assign, c_sums, c_sq,
                    k=k, backend=backend)
                init_rounds = c_rounds
                n_changed = int(nc)
            else:
                init_sums = jnp.zeros_like(c_sums)
                init_sqsums = jnp.zeros_like(c_sq)
        sr = _swap_search_jit(data, d1, d2, assign, med_mask, key, cache,
                              dwarm, perm, init_sums, init_sqsums,
                              init_rounds, backend=backend, metric=metric,
                              batch_size=B, delta=delta, k=k,
                              sampling=sampling, baseline=baseline,
                              early_stop=early_stop, mode=mode,
                              free_rounds=free_rounds)
        if mode == "pic":
            cache2 = sr.aux
            fresh = int(fresh_positions(cache, cache2))
        else:
            cache2 = cache
            fresh = int(sr.n_evals)
        m_idx, x_idx = divmod(int(sr.best), n)
        cand = medoids.at[m_idx].set(x_idx)
        new_loss = total_loss(data, cand, metric=metric)
        # Same f32 accept rule as the fused step (see _swap_iter).
        accept = new_loss < prev_loss - 1e-7 * jnp.maximum(
            1.0, jnp.abs(prev_loss))
        new_carry = (sr.sums, sr.sqsums, sr.rounds, d1, d2, assign)
        old_med = medoids[m_idx]
        new_mask = med_mask.at[old_med].set(False).at[x_idx].set(True)
        return (int(sr.best), new_loss, cand, new_mask, old_med, new_carry,
                cache2, fresh, int(sr.n_evals_cached), n_changed,
                int(sr.used_exact), accept)

    # -- public ----------------------------------------------------------
    def fit(self, data, warm_start=None) -> FitResult:
        """Fit medoids; ``warm_start`` (optional ``[k]`` indices) skips
        BUILD and seeds SWAP from the given medoids.

        The warm path is the serving layer's incremental refit: BUILD's
        ``n·k + rounds`` evaluations are never paid (the build ledger
        entry records 0), the context key is still drawn first so a
        ``reuse="pic"`` ring fills identically to a cold fit, and the
        BUILD subkeys are simply not consumed — the SWAP chain is
        deterministic given (seed, warm_start) but intentionally distinct
        from the cold fit's chain.
        """
        with host_stage("fit staging: input upload"):
            data = jnp.asarray(data, jnp.float32)
        n = data.shape[0]
        if n <= self.k:
            raise ValueError("need n > k")
        backend = resolve_stats_backend(self.backend, self.metric)
        res = FitResult(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0)
        with host_stage("fit staging: RNG chain head + context upload"):
            key = jax.random.PRNGKey(self.seed)
            key, ckey = jax.random.split(key)
            ctx = self._make_context(data, ckey, backend, res)
            if warm_start is not None:
                ws = np.asarray(warm_start, np.int64).ravel()
                if ws.shape[0] != self.k or len(set(ws.tolist())) != self.k:
                    raise ValueError(
                        f"warm_start must be {self.k} distinct medoid "
                        f"indices, got {ws.tolist()}")
                if ws.min() < 0 or ws.max() >= n:
                    raise ValueError(f"warm_start indices out of range "
                                     f"[0, {n})")
                ctx.warm_medoids = jnp.asarray(ws, jnp.int32)
        t0 = time.perf_counter()
        if ctx.warm_medoids is not None:
            medoids = ctx.warm_medoids
            with host_stage("warm-start staging: medoid mask upload"):
                med_mask = jnp.zeros((n,), jnp.bool_).at[medoids].set(True)
            res.evals_by_phase["build"] = 0
        else:
            medoids, med_mask, key = self._build(data, key, ctx, res)
        jax.block_until_ready(medoids)
        res.wall_by_phase["build"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        medoids, loss, converged = self._swap(data, medoids, med_mask, key,
                                              ctx, res)
        res.wall_by_phase["swap"] = time.perf_counter() - t0
        res.medoids = np.asarray(host_read(medoids))
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = sum(v for ph, v in res.evals_by_phase.items()
                                 if not ph.endswith("_cached"))
        res.cached_evals = sum(v for ph, v in res.evals_by_phase.items()
                               if ph.endswith("_cached"))
        # Feed the measured phase walls back to the tile tuner: the next
        # resolve for this (n, d, k, device, backend) shape class prefers
        # the fastest observed config over the VMEM heuristic.
        observe_tiles(n, data.shape[1], self.k,
                      resolve_tile_config(n, data.shape[1], self.k,
                                          backend=backend),
                      res.wall_by_phase, backend=backend)
        return res

    def fit_batch(self, datasets, seeds=None) -> BatchFitReport:
        """Fit a batch of INDEPENDENT datasets in one dispatch per phase.

        Args:
          datasets: a ``[B, n, d]`` array, or a list of ``[n_i, d]``
            arrays with ragged ``n_i`` (padded internally to the batch
            maximum; pad rows are masked out of every sum, can never
            become medoids, and carry zero reference weight).
          seeds: optional per-fit RNG seeds, length B; default: every fit
            uses ``self.seed`` (fits are still independent — they see
            different data).

        Each fit reproduces ``BanditPAM(seed=seeds[i]).fit(datasets[i])``
        bit-identically — same medoids, loss, and fresh/cached ledger —
        because every lane replays the single-fit trace: the per-fit RNG
        chain (context key, k BUILD subkeys, per-iteration SWAP subkeys,
        per-search reference permutations) is replicated host-side with
        the same ``jax.random`` ops, the per-fit budget/δ ride in as
        traced ``n_valid``/``log_term`` data, and the batch axis is a
        ``lax.map`` scan (see ``_build_batch``).  Requires
        ``sampling="permutation"`` and ``cache_cols=0``; under
        ``reuse="pic"`` the ring width is resolved from the LARGEST fit,
        so the ragged-parity guarantee holds as long as no fit recycles
        (the default width covers every fit that would not recycle
        solo — see docs/design.md).

        Returns a :class:`BatchFitReport`: per-fit :class:`FitReport`
        list plus batch-level ``dispatches_by_phase`` (one per phase,
        measured) and ``wall_by_phase``.
        """
        if self.sampling != "permutation":
            raise ValueError('fit_batch requires sampling="permutation" '
                             "(per-fit reference layouts are precomputed)")
        if self.cache_cols > 0:
            raise ValueError("fit_batch does not support cache_cols warm "
                             "blocks (ragged per-fit warm widths would "
                             "need per-fit traces); use reuse='pic'")
        if isinstance(datasets, (list, tuple)):
            arrs = [np.asarray(a, np.float32) for a in datasets]
        else:
            a = np.asarray(datasets, np.float32)
            if a.ndim != 3:
                raise ValueError(f"expected [B, n, d] batch or a list of "
                                 f"[n_i, d] arrays, got shape {a.shape}")
            arrs = [a[i] for i in range(a.shape[0])]
        if not arrs:
            raise ValueError("empty batch")
        if any(x.ndim != 2 for x in arrs):
            raise ValueError("every dataset must be [n_i, d]")
        if len({x.shape[1] for x in arrs}) != 1:
            raise ValueError("all datasets must share the feature dim")
        ns = [x.shape[0] for x in arrs]
        if min(ns) <= self.k:
            raise ValueError("need n > k in every dataset")
        if seeds is None:
            seeds = [self.seed] * len(arrs)
        seeds = [int(s) for s in seeds]
        if len(seeds) != len(arrs):
            raise ValueError(f"{len(seeds)} seeds for {len(arrs)} datasets")

        bf, n_max, dim = len(arrs), max(ns), arrs[0].shape[1]
        k, B, T = self.k, self.batch_size, self.max_swaps
        backend = resolve_stats_backend(self.backend, self.metric)
        pic = self.reuse == "pic"
        rb = -(-n_max // B) * B           # search-layout width (R·B)
        data = np.zeros((bf, n_max, dim), np.float32)
        valid = np.zeros((bf, n_max), bool)
        for i, x in enumerate(arrs):
            data[i, : ns[i]] = x
            valid[i, : ns[i]] = True

        # -- host-side replication of every per-fit RNG chain ------------
        # (jax.random keys/splits/permutations are deterministic bit ops,
        # identical inside and outside jit — and identical under vmap, so
        # the whole batch's chains are ONE dispatch plus one permutation
        # dispatch per distinct n, not ~70 tiny ops per fit)
        spw = np.zeros((bf, rb), np.float32)
        log_b = np.zeros((bf,), np.float32)
        log_s = np.zeros((bf,), np.float32)
        sp_build = None if pic else np.zeros((bf, k, rb), np.int32)
        sp_swap = None if pic else np.zeros((bf, T, rb), np.int32)
        sp_pic = np.zeros((bf, rb), np.int32) if pic else None
        if pic:
            wcap = resolve_batch_cache_rounds(ns, B, self.cache_width)
            pidx_c = np.zeros((bf, wcap * B), np.int32)
            pw_c = np.zeros((bf, wcap * B), np.float32)
        else:
            wcap, pidx_c, pw_c = 0, None, None

        with host_stage("fit_batch staging: per-fit RNG chain replication"):
            ckeys, bkeys, skeys, bpk, spk = _batch_rng_chains(
                jnp.asarray(seeds), k=k, T=T)
            bkeys, skeys = np.asarray(bkeys), np.asarray(skeys)

        def tiled(perm_np, width):
            return np.tile(perm_np, -(-width // perm_np.shape[-1])
                           )[..., :width]

        by_n: dict = {}
        for i, n_i in enumerate(ns):
            by_n.setdefault(n_i, []).append(i)
        with host_stage("fit_batch staging: per-fit reference permutations"):
            for n_i, idxs in by_n.items():
                ii = np.asarray(idxs)
                if pic:
                    # one fixed permutation per fit, from the context key
                    perms = np.asarray(_batch_perms(ckeys[ii], n=n_i))
                    sp_pic[ii] = tiled(perms, rb)
                    pidx_c[ii] = tiled(perms, wcap * B)
                    pw_c[ii] = np.arange(wcap * B) < n_i
                else:
                    # one permutation per search: k BUILD + T SWAP, batched
                    pkeys = jnp.concatenate(
                        [bpk[ii].reshape(-1, 2), spk[ii].reshape(-1, 2)])
                    perms = np.asarray(_batch_perms(pkeys, n=n_i))
                    g = len(ii)
                    sp_build[ii] = tiled(perms[:g * k].reshape(g, k, n_i),
                                         rb)
                    sp_swap[ii] = tiled(perms[g * k:].reshape(g, T, n_i),
                                        rb)
        for i, n_i in enumerate(ns):
            spw[i] = np.arange(rb) < n_i
        d_b = [self.delta if self.delta is not None
               else 1.0 / (1000.0 * n_i) for n_i in ns]
        d_s = [self.delta if self.delta is not None
               else 1.0 / (1000.0 * k * n_i) for n_i in ns]
        # bit-for-bit the expression adaptive_search folds at trace time,
        # jnp.float32(jnp.log(1.0 / d)): the reciprocal in f64, the cast
        # and the log in f32 — vectorised to two dispatches for the batch
        with host_stage("fit_batch staging: folded log(1/delta) terms"):
            log_b[:] = np.asarray(jnp.log(jnp.asarray(
                1.0 / np.asarray(d_b, np.float64), jnp.float32)))
            log_s[:] = np.asarray(jnp.log(jnp.asarray(
                1.0 / np.asarray(d_s, np.float64), jnp.float32)))

        # The batched FitContext: same container as the single-fit path,
        # leading [batch] axis on every array field (batch > 0).
        with host_stage("fit_batch staging: batched context + data upload"):
            ctx = FitContext(
                mode="pic" if pic else "none", backend=backend,
                perm_idx=None if pidx_c is None else jnp.asarray(pidx_c),
                perm_w=None if pw_c is None else jnp.asarray(pw_c),
                cache=(PicCache(
                    cols=jnp.zeros((bf, n_max, wcap * B), jnp.float32),
                    hw=jnp.zeros((bf,), jnp.int32),
                    fresh_pos=jnp.zeros((bf,), jnp.uint32)) if pic else None),
                batch=bf, valid=jnp.asarray(valid),
                n_valid=jnp.asarray(ns, jnp.int32),
                log_build=jnp.asarray(log_b), log_swap=jnp.asarray(log_s),
                spidx_build=jnp.asarray(sp_pic if pic else sp_build),
                spidx_swap=jnp.asarray(sp_pic if pic else sp_swap),
                spw=jnp.asarray(spw))
            dataj = jnp.asarray(data)
            bkeys_j, skeys_j = jnp.asarray(bkeys), jnp.asarray(skeys)
        disp: dict = {}
        kw = dict(backend=backend, metric=self.metric, batch_size=B,
                  delta=self.delta, sampling=self.sampling,
                  baseline=self.baseline, k=k, mode=ctx.mode, free_rounds=0)

        t0 = time.perf_counter()
        bphase = counted_dispatch(_build_batch, disp, "build")
        (med_mask, medoids, cache, rounds_a, evals_a, cached_a) = bphase(
            dataj, bkeys_j, ctx.cache, ctx.spidx_build, ctx.spw,
            ctx.valid, ctx.n_valid, ctx.log_build, **kw)
        jax.block_until_ready(medoids)
        ctx.cache = cache
        wall = {"build": time.perf_counter() - t0}

        kw.pop("sampling")
        t0 = time.perf_counter()
        sphase = counted_dispatch(_swap_batch, disp, "swap")
        (meds_f, loss_f, conv, iters, fresh_s, cached_s, nchg_s, exact_s,
         old_a, new_a, loss_a, acc_a) = sphase(
             dataj, medoids, med_mask, skeys_j, ctx.cache,
             ctx.perm_idx, ctx.perm_w, ctx.spidx_swap, ctx.spw, ctx.valid,
             ctx.n_valid, ctx.log_swap, sampling=self.sampling,
             early_stop=self.swap_early_stop, max_swaps=T, **kw)
        jax.block_until_ready(loss_f)
        wall["swap"] = time.perf_counter() - t0

        # -- per-fit ledger assembly (host ints: no uint32 wrap) ---------
        # ONE explicit device→host read for the whole batch: every
        # medoid/loss/ledger array comes back in a single device_get, so
        # the batch driver mirrors the single-fit guard contract (one
        # dispatch per phase + sanctioned reads only).
        (meds_np, loss_np, conv_np, iters_np, rounds_np, bev_np, bca_np,
         fresh_np, cached_np, nchg_np, exact_np, old_np, new_np, la_np,
         acc_np) = host_read(
            (meds_f, loss_f, conv, iters, rounds_a, evals_a, cached_a,
             fresh_s, cached_s, nchg_s, exact_s, old_a, new_a, loss_a,
             acc_a))
        iters_np = np.asarray(iters_np, np.int64)
        rounds_np = np.asarray(rounds_np, np.int64)
        bev_np = np.asarray(bev_np, np.int64)
        bca_np = np.asarray(bca_np, np.int64)
        fresh_np, cached_np = (np.asarray(fresh_np, np.int64),
                               np.asarray(cached_np, np.int64))
        nchg_np, exact_np = (np.asarray(nchg_np, np.int64),
                             np.asarray(exact_np, np.int64))
        reports = []
        for i, n_i in enumerate(ns):
            scale = n_i if pic else 1
            res = FitReport(medoids=meds_np[i].astype(np.int64),
                            loss=float(loss_np[i]), n_swaps=0,
                            converged=bool(conv_np[i]), distance_evals=0)
            res.build_rounds = [int(r) for r in rounds_np[i]]
            res.evals_by_phase["build"] = (scale * int(bev_np[i].sum())
                                           + n_i * k)
            if pic:
                res.evals_by_phase["build_cached"] = int(bca_np[i].sum())
            it = int(iters_np[i])
            res.evals_by_phase["swap"] = (it * 2 * n_i * k
                                          + scale * int(fresh_np[i]))
            if pic:
                res.evals_by_phase["swap_cached"] = (
                    int(cached_np[i]) + n_i * int(nchg_np[i]))
            res.swap_exact_fallbacks = int(exact_np[i])
            for t in range(it):
                if acc_np[i, t]:
                    res.swap_history.append((int(old_np[i, t]),
                                             int(new_np[i, t]),
                                             float(la_np[i, t])))
            res.n_swaps = len(res.swap_history)
            res.distance_evals = sum(
                v for ph, v in res.evals_by_phase.items()
                if not ph.endswith("_cached"))
            res.cached_evals = sum(
                v for ph, v in res.evals_by_phase.items()
                if ph.endswith("_cached"))
            reports.append(res)
        return BatchFitReport(reports=reports, medoids=meds_np,
                              loss=loss_np.astype(np.float64),
                              n_valid=np.asarray(ns, np.int64),
                              wall_by_phase=wall, dispatches_by_phase=disp)

    def fit_predict(self, data) -> np.ndarray:
        """Fit and return the in-sample cluster labels, [n] — the sklearn
        convention.  (The legacy ``(FitReport, labels)`` tuple return was
        FutureWarning-deprecated and is now removed; call :meth:`fit` for
        the full report — it carries the same medoids/ledger, and the
        facade ``repro.api.KMedoids`` fills ``report.labels``.)"""
        res = self.fit(data)
        data = jnp.asarray(data, jnp.float32)
        _, _, assign = medoid_cache(data, jnp.asarray(res.medoids,
                                                      jnp.int32),
                                    metric=self.metric)
        return np.asarray(assign)
