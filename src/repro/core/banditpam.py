"""BanditPAM: the paper's algorithm — BUILD + SWAP driven by Algorithm 1.

Faithful to the paper:

* BUILD (Eq. 6): arms = candidate points, ``g_x(y) = (d(x,y) − d_near(y)) ∧ 0``
  against the cached nearest-medoid distance; the first assignment uses
  ``g_x(y) = d(x,y)`` (Eq. 4 with an empty medoid set).
* SWAP (Eq. 7 + Appendix Eq. 12 / FastPAM1): arms = (medoid m, candidate x)
  pairs.  One distance ``d(x,y)`` serves all k arms ``(·, x)`` via the cached
  ``d₁, d₂`` and cluster assignment — evaluated here as a base term plus a
  one-hot matmul correction, which never materialises a ``[k, n, B]`` tensor:

      g_{m,x}(y) = −d₁(y) + 1[y∉C_m]·min(d₁(y), d(x,y))
                           + 1[y∈C_m]·min(d₂(y), d(x,y))
                 = base_x(y) + 1[y∈C_m]·corr_x(y)
      base_x(y) = min(d₁(y), d(x,y)) − d₁(y)
      corr_x(y) = min(d₂(y), d(x,y)) − min(d₁(y), d(x,y))

* σ_x re-estimated from the first batch of every Algorithm 1 call (Eq. 11,
  Appendix 1.2), B = 100, δ = 1/(1000·|S_tar|) by default (§3.2).
* SWAP iterations repeat until the chosen swap no longer improves the exact
  loss, with a hard cap T (paper §4 Remark 1).

Distance-evaluation accounting (the paper's headline metric) is algorithmic:
each bandit round pays ``#active-arms × B`` in BUILD and
``#distinct-active-candidates × B`` in SWAP (FastPAM1 sharing), cache
(re)computation pays ``n·k``, and the d_near update after each BUILD
assignment pays ``n`` — exactly the ledger of the reference implementation.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import SearchResult, adaptive_search
from .distances import get_metric

_EXACT_CHUNK = 512  # reference-chunk size for exact fallback passes


# ---------------------------------------------------------------------------
# Shared cache / loss helpers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric",))
def medoid_cache(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """d1 (nearest-medoid dist), d2 (second nearest), assignment; [n] each."""
    dmat = get_metric(metric)(data, data[medoids])          # [n, k]
    assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
    d1 = jnp.min(dmat, axis=1)
    dmat2 = dmat.at[jnp.arange(dmat.shape[0]), assign].set(jnp.inf)
    d2 = jnp.min(dmat2, axis=1)
    return d1, d2, assign


@functools.partial(jax.jit, static_argnames=("metric",))
def total_loss(data: jnp.ndarray, medoids: jnp.ndarray, *, metric: str) -> jnp.ndarray:
    dmat = get_metric(metric)(data, data[medoids])
    return jnp.sum(jnp.min(dmat, axis=1))


def _ref_chunks(n_ref: int, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
    """Static index/weight tiling of [0, n_ref) into equal chunks."""
    n_chunks = -(-n_ref // chunk)
    idx = np.arange(n_chunks * chunk)
    w = (idx < n_ref).astype(np.float32)
    idx = np.minimum(idx, n_ref - 1)
    return idx.reshape(n_chunks, chunk), w.reshape(n_chunks, chunk)


# ---------------------------------------------------------------------------
# BUILD
# ---------------------------------------------------------------------------

def _build_g(dxy: jnp.ndarray, dnear_b: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6 with the Eq. 4 special-case for the first assignment."""
    dn = dnear_b[None, :]
    return jnp.where(jnp.isinf(dn), dxy, jnp.minimum(dxy - dn, 0.0))


@functools.partial(jax.jit,
                   static_argnames=("metric", "batch_size", "delta", "sampling",
                                    "baseline", "free_rounds"))
def _build_search(data: jnp.ndarray, dnear: jnp.ndarray, med_mask: jnp.ndarray,
                  key: jax.Array, *, metric: str, batch_size: int,
                  delta: float, sampling: str = "permutation",
                  baseline: str = "none", perm=None, dwarm=None,
                  free_rounds: int = 0) -> SearchResult:
    n = data.shape[0]
    dist = get_metric(metric)

    def stats_fn(ref_idx, w, lead, rnd):
        if dwarm is None:
            dxy = dist(data, data[ref_idx])
        else:
            # paper App 2.2 cache: warm rounds read precomputed distance
            # columns (same fixed permutation across every search call)
            dxy = jax.lax.cond(
                rnd < free_rounds,
                lambda _: jax.lax.dynamic_slice_in_dim(
                    dwarm, rnd * batch_size, batch_size, 1),
                lambda _: dist(data, data[ref_idx]), None)
        g = _build_g(dxy, dnear[ref_idx]) * w[None, :]             # [n, B]
        cross = g @ g[lead]
        return jnp.sum(g, axis=1), jnp.sum(g * g, axis=1), cross

    def exact_fn():
        idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, wc = iw
            g = _build_g(dist(data, data[i]), dnear[i])
            return acc + jnp.sum(g * wc[None, :], axis=1), None

        sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32), (idx, w))
        return sums / n

    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=n, n_ref=n, batch_size=batch_size,
                           delta=delta, active_init=jnp.logical_not(med_mask),
                           sampling=sampling, baseline=baseline, perm=perm,
                           free_rounds=free_rounds)


# ---------------------------------------------------------------------------
# SWAP (FastPAM1 fused form)
# ---------------------------------------------------------------------------

def _swap_terms(dxy: jnp.ndarray, d1_b: jnp.ndarray, d2_b: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    base = jnp.minimum(dxy, d1_b[None, :]) - d1_b[None, :]
    corr = jnp.minimum(dxy, d2_b[None, :]) - jnp.minimum(dxy, d1_b[None, :])
    return base, corr


def _swap_batch_stats(dxy, d1_b, d2_b, a_b, w, k, lead=None):
    """Per-arm (m·n + x) sums, square-sums (and optional leader cross-sums)
    over a reference batch.

    g = base + 1[assign==m]·corr  ⇒
      Σ g        = Σ base + Σ_{y∈C_m} corr
      Σ g²       = Σ base² + Σ_{y∈C_m} (2·base·corr + corr²)
      Σ g·g_lead = Σ base·g_lead + Σ_{y∈C_m} corr·g_lead
    The C_m-restricted sums are one-hot matmuls (MXU-shaped).
    """
    n = dxy.shape[0]
    base, corr = _swap_terms(dxy, d1_b, d2_b)
    # weights are {0,1} (padding mask), so w² = w and masking base once is
    # enough for every product below.
    base = base * w[None, :]
    onehot = jax.nn.one_hot(a_b, k, dtype=dxy.dtype) * w[:, None]   # [B, k]
    sums = jnp.sum(base, axis=1)[None, :] + (corr @ onehot).T       # [k, n]
    sq_base = jnp.sum(base * base, axis=1)
    sq_cross = 2.0 * base * corr + corr * corr
    sqsums = sq_base[None, :] + (sq_cross @ onehot).T
    if lead is None:
        return sums.reshape(-1), sqsums.reshape(-1)
    m_l, x_l = lead // n, lead % n
    g_lead = base[x_l] + onehot[:, m_l] * corr[x_l]                 # [B], w-masked
    cross = (base @ g_lead)[None, :] + ((corr * g_lead[None, :]) @ onehot).T
    return sums.reshape(-1), sqsums.reshape(-1), cross.reshape(-1)


@functools.partial(jax.jit,
                   static_argnames=("metric", "batch_size", "delta", "k",
                                    "sampling", "baseline", "early_stop",
                                    "free_rounds"))
def _swap_search(data: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                 assign: jnp.ndarray, med_mask: jnp.ndarray, key: jax.Array,
                 *, metric: str, batch_size: int, delta: float, k: int,
                 sampling: str = "permutation", baseline: str = "none",
                 early_stop: bool = False, perm=None, dwarm=None,
                 free_rounds: int = 0) -> SearchResult:
    n = data.shape[0]
    dist = get_metric(metric)

    def stats_fn(ref_idx, w, lead, rnd):
        if dwarm is None:
            dxy = dist(data, data[ref_idx])                  # [n, B]
        else:
            dxy = jax.lax.cond(
                rnd < free_rounds,
                lambda _: jax.lax.dynamic_slice_in_dim(
                    dwarm, rnd * batch_size, batch_size, 1),
                lambda _: dist(data, data[ref_idx]), None)
        return _swap_batch_stats(dxy, d1[ref_idx], d2[ref_idx],
                                 assign[ref_idx], w, k, lead=lead)

    def exact_fn():
        idx_np, w_np = _ref_chunks(n, _EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, wc = iw
            dxy = dist(data, data[i])
            s, _ = _swap_batch_stats(dxy, d1[i], d2[i], assign[i], wc, k)
            return acc + s, None

        sums, _ = jax.lax.scan(body, jnp.zeros((k * n,), jnp.float32), (idx, w))
        return sums / n

    # Candidates that are already medoids are not valid swap targets.
    active0 = jnp.tile(jnp.logical_not(med_mask)[None, :], (k, 1)).reshape(-1)

    def count_fn(active):
        # FastPAM1: one distance per (x, y) pair serves all k arms (·, x).
        any_x = jnp.any(active.reshape(k, n), axis=0)
        return jnp.sum(any_x.astype(jnp.uint32))

    return adaptive_search(key, stats_fn=stats_fn, exact_fn=exact_fn,
                           n_arms=k * n, n_ref=n, batch_size=batch_size,
                           delta=delta, active_init=active0, count_fn=count_fn,
                           sampling=sampling, baseline=baseline,
                           stop_when_positive=early_stop, perm=perm,
                           free_rounds=free_rounds)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

@dataclass
class FitResult:
    medoids: np.ndarray
    loss: float
    n_swaps: int
    converged: bool
    distance_evals: int
    evals_by_phase: Dict[str, int] = field(default_factory=dict)
    swap_history: List[Tuple[int, int, float]] = field(default_factory=list)
    build_rounds: List[int] = field(default_factory=list)
    swap_exact_fallbacks: int = 0


class BanditPAM:
    """k-medoids via adaptive sampling; same medoids as PAM w.h.p."""

    def __init__(self, k: int, metric: str = "l2", batch_size: int = 100,
                 delta: Optional[float] = None, max_swaps: Optional[int] = None,
                 seed: int = 0, sampling: str = "permutation",
                 baseline: str = "none", swap_early_stop: bool = False,
                 cache_cols: int = 0):
        self.k = int(k)
        self.metric = metric
        self.batch_size = int(batch_size)
        self.delta = delta
        self.max_swaps = max_swaps if max_swaps is not None else 4 * self.k + 10
        self.seed = seed
        self.sampling = sampling
        self.baseline = baseline
        self.swap_early_stop = swap_early_stop
        self.cache_cols = cache_cols

    # -- BUILD ----------------------------------------------------------
    def _make_cache(self, data: jnp.ndarray, key: jax.Array, res: FitResult):
        """Paper App 2.2: one fixed reference permutation for every search
        + a warm block of its first C distance columns, paid once."""
        n = data.shape[0]
        if self.cache_cols <= 0 or self.sampling != "permutation":
            return None, None, 0
        c = (min(self.cache_cols, n) // self.batch_size) * self.batch_size
        if c <= 0:
            return None, None, 0
        perm = jax.random.permutation(key, n).astype(jnp.int32)
        dwarm = get_metric(self.metric)(data, data[perm[:c]])
        res.evals_by_phase["cache_warm"] = n * c
        return perm, dwarm, c // self.batch_size

    def _build(self, data: jnp.ndarray, key: jax.Array, res: FitResult):
        n = data.shape[0]
        dist = get_metric(self.metric)
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * n)
        dnear = jnp.full((n,), jnp.inf, jnp.float32)
        med_mask = jnp.zeros((n,), jnp.bool_)
        medoids: List[int] = []
        build_evals = 0
        for _ in range(self.k):
            key, sub = jax.random.split(key)
            sr = _build_search(data, dnear, med_mask, sub, metric=self.metric,
                               batch_size=self.batch_size, delta=delta,
                               sampling=self.sampling, baseline=self.baseline,
                               perm=self._perm, dwarm=self._dwarm,
                               free_rounds=self._free_rounds)
            m = int(sr.best)
            medoids.append(m)
            med_mask = med_mask.at[m].set(True)
            drow = dist(data[m][None, :], data)[0]
            dnear = jnp.minimum(dnear, drow)
            build_evals += int(sr.n_evals) + n
            res.build_rounds.append(int(sr.rounds))
        res.evals_by_phase["build"] = build_evals
        return jnp.asarray(medoids, jnp.int32), med_mask, key

    # -- SWAP -----------------------------------------------------------
    def _swap(self, data: jnp.ndarray, medoids: jnp.ndarray,
              med_mask: jnp.ndarray, key: jax.Array, res: FitResult):
        n = data.shape[0]
        delta = self.delta if self.delta is not None else 1.0 / (1000.0 * self.k * n)
        swap_evals = 0
        loss = float(total_loss(data, medoids, metric=self.metric))
        converged = False
        for _ in range(self.max_swaps):
            d1, d2, assign = medoid_cache(data, medoids, metric=self.metric)
            swap_evals += n * self.k
            key, sub = jax.random.split(key)
            sr = _swap_search(data, d1, d2, assign, med_mask, sub,
                              metric=self.metric, batch_size=self.batch_size,
                              delta=delta, k=self.k, sampling=self.sampling,
                              baseline=self.baseline,
                              early_stop=self.swap_early_stop,
                              perm=self._perm, dwarm=self._dwarm,
                              free_rounds=self._free_rounds)
            swap_evals += int(sr.n_evals)
            res.swap_exact_fallbacks += int(sr.used_exact)
            m_idx, x_idx = divmod(int(sr.best), n)
            cand = medoids.at[m_idx].set(x_idx)
            new_loss = float(total_loss(data, cand, metric=self.metric))
            swap_evals += n * self.k
            if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
                old = int(medoids[m_idx])
                medoids = cand
                med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
                res.swap_history.append((old, x_idx, new_loss))
                loss = new_loss
            else:
                converged = True
                break
        res.evals_by_phase["swap"] = swap_evals
        return medoids, loss, converged

    # -- public ----------------------------------------------------------
    def fit(self, data) -> FitResult:
        data = jnp.asarray(data, jnp.float32)
        if data.shape[0] <= self.k:
            raise ValueError("need n > k")
        key = jax.random.PRNGKey(self.seed)
        res = FitResult(medoids=np.zeros(self.k, np.int64), loss=np.inf,
                        n_swaps=0, converged=False, distance_evals=0)
        key, ckey = jax.random.split(key)
        self._perm, self._dwarm, self._free_rounds = self._make_cache(
            data, ckey, res)
        medoids, med_mask, key = self._build(data, key, res)
        medoids, loss, converged = self._swap(data, medoids, med_mask, key, res)
        res.medoids = np.asarray(medoids)
        res.loss = loss
        res.n_swaps = len(res.swap_history)
        res.converged = converged
        res.distance_evals = sum(res.evals_by_phase.values())
        return res

    def fit_predict(self, data) -> Tuple[FitResult, np.ndarray]:
        res = self.fit(data)
        data = jnp.asarray(data, jnp.float32)
        _, _, assign = medoid_cache(data, jnp.asarray(res.medoids),
                                    metric=self.metric)
        return res, np.asarray(assign)
