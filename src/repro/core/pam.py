"""Exact PAM and FastPAM1 — the deterministic oracles BanditPAM must match.

Both produce *identical* medoids (FastPAM1 is an algebraic rewrite of PAM's
SWAP search, Appendix 1.1); they differ only in distance-evaluation cost:
PAM pays ``k·n²`` per SWAP iteration, FastPAM1 pays ``n²``.  BUILD costs
``n²`` per assignment for both (with the d_near cache).

The argmin tie-breaking (flattened ``m·n + x``, lowest index) matches
``repro.core.banditpam`` exactly, so "same trajectory" tests are meaningful.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .banditpam import (_build_g, _ref_chunks, _swap_batch_stats,
                        medoid_cache, total_loss)
from .distances import get_metric
from .report import FitReport

_CHUNK = 512


@functools.partial(jax.jit, static_argnames=("metric",))
def _build_mu_exact(data: jnp.ndarray, dnear: jnp.ndarray, *, metric: str) -> jnp.ndarray:
    n = data.shape[0]
    dist = get_metric(metric)
    idx_np, w_np = _ref_chunks(n, _CHUNK)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

    def body(acc, iw):
        i, wc = iw
        g = _build_g(dist(data, data[i]), dnear[i])
        return acc + jnp.sum(g * wc[None, :], axis=1), None

    sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32), (idx, w))
    return sums / n


@functools.partial(jax.jit, static_argnames=("metric", "k"))
def _swap_mu_exact(data: jnp.ndarray, d1: jnp.ndarray, d2: jnp.ndarray,
                   assign: jnp.ndarray, *, metric: str, k: int) -> jnp.ndarray:
    n = data.shape[0]
    dist = get_metric(metric)
    idx_np, w_np = _ref_chunks(n, _CHUNK)
    idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

    def body(acc, iw):
        i, wc = iw
        dxy = dist(data, data[i])
        s, _ = _swap_batch_stats(dxy, d1[i], d2[i], assign[i], wc, k)
        return acc + s, None

    sums, _ = jax.lax.scan(body, jnp.zeros((k * n,), jnp.float32), (idx, w))
    return sums / n


# Alias of the unified report type (see repro.core.report).
PAMResult = FitReport


def pam(data, k: int, metric: str = "l2", max_swaps: int | None = None,
        fastpam1: bool = True) -> PAMResult:
    """Exact PAM (FastPAM1 accounting when ``fastpam1=True``)."""
    data = jnp.asarray(data, jnp.float32)
    n = data.shape[0]
    max_swaps = max_swaps if max_swaps is not None else 4 * k + 10
    dist = get_metric(metric)

    res = PAMResult(medoids=np.zeros(k, np.int64), loss=np.inf, n_swaps=0,
                    converged=False, distance_evals=0)

    # ---- BUILD ----
    dnear = jnp.full((n,), jnp.inf, jnp.float32)
    med_mask = jnp.zeros((n,), jnp.bool_)
    medoids: List[int] = []
    build_evals = 0
    for _ in range(k):
        mu = _build_mu_exact(data, dnear, metric=metric)
        mu = jnp.where(med_mask, jnp.inf, mu)
        m = int(jnp.argmin(mu))
        medoids.append(m)
        med_mask = med_mask.at[m].set(True)
        dnear = jnp.minimum(dnear, dist(data[m][None, :], data)[0])
        build_evals += n * n
    res.evals_by_phase["build"] = build_evals

    # ---- SWAP ----
    med = jnp.asarray(medoids, jnp.int32)
    loss = float(total_loss(data, med, metric=metric))
    swap_evals = 0
    converged = False
    for _ in range(max_swaps):
        d1, d2, assign = medoid_cache(data, med, metric=metric)
        mu = _swap_mu_exact(data, d1, d2, assign, metric=metric, k=k)
        mu = jnp.where(jnp.tile(med_mask[None, :], (k, 1)).reshape(-1),
                       jnp.inf, mu)
        best = int(jnp.argmin(mu))
        swap_evals += (n * n) if fastpam1 else (k * n * n)
        m_idx, x_idx = divmod(best, n)
        cand = med.at[m_idx].set(x_idx)
        new_loss = float(total_loss(data, cand, metric=metric))
        if new_loss < loss - 1e-7 * max(1.0, abs(loss)):
            old = int(med[m_idx])
            med = cand
            med_mask = med_mask.at[old].set(False).at[x_idx].set(True)
            res.swap_history.append((old, x_idx, new_loss))
            loss = new_loss
        else:
            converged = True
            break
    res.evals_by_phase["swap"] = swap_evals

    res.medoids = np.asarray(med)
    res.loss = loss
    res.n_swaps = len(res.swap_history)
    res.converged = converged
    res.distance_evals = build_evals + swap_evals
    return res
