"""Algorithm 1 of the paper: Adaptive-Search(S_tar, S_ref, g, B, delta, sigma).

A batched UCB / successive-elimination best-arm routine, recast for TPU:

* The arm set is *static* — eliminated arms are masked, not removed, so the
  whole search is a single ``lax.while_loop`` with fixed shapes (hardware
  adaptation #1 in docs/design.md).  The *algorithmic* number of distance
  evaluations (what the paper counts and what real hardware pays with the
  compacted execution) is tracked exactly via ``count_fn``.
* Arm statistics are streamed: ``stats_fn`` returns per-arm batch *sums*,
  *square-sums* and *leader cross-sums*, never materialising an
  ``[arms, B]`` tensor in HBM.  This is what allows the SWAP step to use the
  FastPAM1 rewrite (one distance per ``(x, y)`` shared across all k
  medoid-arms) as a single matmul.

Two sampling modes:

* ``"replacement"`` — the paper's §3.2 literal procedure: i.i.d. uniform
  batches; if the budget (``n_used ≥ |S_ref|``) is exhausted with >1
  surviving arm, survivors are resolved exactly (Algorithm 1 lines 13–15).
* ``"permutation"`` (default) — the paper's own Appendix 2.2 refinement:
  batches are consecutive slices of a fixed random permutation of S_ref
  (sampling without replacement).  The confidence interval gains a
  finite-population factor ``sqrt(1 − n_used/n_ref)`` (Serfling/Hoeffding
  for simple random sampling), so at full budget the running mean *is* the
  exact mean and CI = 0 — survivors resolve without the separate exact
  pass.  Theorem 2's proof does not require cross-round independence of the
  reference sampling, so correctness guarantees carry over.

Beyond-paper optimization (``baseline="leader"``): every arm is evaluated on
the *same* reference batch, so for any two arms the difference estimator
``μ̂_x − μ̂_lead`` has variance ``Var(g_x(J) − g_lead(J))`` — typically far
smaller than ``σ_x² + σ_lead²`` for the near-optimal arms that dominate the
paper's cost bound (their g-returns are strongly positively correlated).
After a pilot round picks a leader, we additionally track differenced
statistics ``D_x = g_x − g_lead`` and eliminate on *either* the raw CI rule
(paper) or the differenced CI rule.  Both are valid 1−δ confidence
sequences for quantities whose argmin is the same arm, so the union-bound
correctness argument of Theorem 1 carries through (with 2δ in place of δ).
Final selection still uses the raw running means (exact at full budget in
permutation mode), so the returned arm matches PAM's argmin exactly.

BanditPAM++ reuse (``init_sums`` / ``init_sqsums`` / ``init_rounds``): in
permutation mode over a FIXED shared permutation, the per-arm moments
accumulated over a prefix of the reference stream are *permutation-invariant
cacheable* — a later call whose arms' ``g`` returns are unchanged (or whose
caller has delta-corrected the moments for the arms that did change) may
seed the search with them and resume mid-stream, paying zero evaluations
for the carried prefix.  See ``repro.core.banditpam`` for the SWAP-phase
driver that exploits this across swap iterations.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# Per-arm sub-Gaussianity floor: keeps CIs finite for degenerate arms whose
# first-batch returns are constant (e.g. duplicated points).
SIGMA_FLOOR = 1e-8

# Deterministic tie-break for the differenced-CI (leader) elimination
# rule: the leader's own differenced statistics are exactly 0, so
# near-leader arms' margins sit on floating-point ties where a ~1e-6
# backend-dependent distance delta (Pallas vs jnp) used to decide kills.
# Requiring the margin to clear a small fraction of the arm's RAW
# confidence width — orders of magnitude above fp noise, orders of
# magnitude below any gap the rule can genuinely resolve — makes the
# per-round survivor sets (and hence the eval ledgers) identical across
# stats backends.  A kill this margin delays is re-taken within a few
# rounds (the CI shrinks as 1/sqrt(t)), so the variance-reduction win is
# untouched.  See docs/design.md, Testing conventions.
LEAD_TIE_REL = 1e-2


class SearchResult(NamedTuple):
    best: jnp.ndarray        # int32 index into the (flattened) arm set
    mu_best: jnp.ndarray     # estimated/exact objective of the winner
    n_evals: jnp.ndarray     # uint32: fresh algorithmic distance evaluations
    rounds: jnp.ndarray      # int32: bandit rounds executed (absolute, incl. carried)
    used_exact: jnp.ndarray  # bool: fell through to exact computation
    n_survivors: jnp.ndarray # int32: surviving arms at loop exit
    n_evals_cached: jnp.ndarray  # uint32: evaluations served from a cache
    sums: jnp.ndarray        # [arms] final Σ g over the consumed prefix
    sqsums: jnp.ndarray      # [arms] final Σ g² over the consumed prefix
    aux: Any = ()            # caller state threaded through the search carry
    #                          (device PIC cache buffer + high-water mark)


class _State(NamedTuple):
    key: jax.Array
    sums: jnp.ndarray        # [arms] Σ g (from round 1, incl. carried seed)
    sqsums: jnp.ndarray      # [arms] Σ g² (carried across calls for PIC reuse)
    sigma: jnp.ndarray       # [arms] per-arm sub-Gaussian scale (Eq. 11)
    active: jnp.ndarray      # [arms] bool survivor mask
    n_used: jnp.ndarray      # int32 reference points consumed so far
    lead: jnp.ndarray        # int32 pilot-round leader (-1 before pilot)
    d_sums: jnp.ndarray      # [arms] Σ (g_x - g_lead) post-pilot
    d_sq: jnp.ndarray        # [arms] Σ (g_x - g_lead)² post-pilot
    sigma_d: jnp.ndarray     # [arms] differenced sub-Gaussian scale
    n_post: jnp.ndarray      # int32 post-pilot samples
    n_evals: jnp.ndarray     # uint32 fresh distance evaluations
    n_cached: jnp.ndarray    # uint32 cache-served distance evaluations
    rounds: jnp.ndarray
    aux: Any                 # caller state (see adaptive_search ``aux_init``)


StatsFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray],
                   Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
ExactFn = Callable[[], jnp.ndarray]
CountFn = Callable[[jnp.ndarray], jnp.ndarray]


def _default_count(active: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(active.astype(jnp.uint32))


def adaptive_search(
    key: jax.Array,
    *,
    stats_fn: StatsFn,
    exact_fn: ExactFn,
    n_arms: int,
    n_ref: int,
    batch_size: int = 100,
    delta: Optional[float] = None,
    active_init: Optional[jnp.ndarray] = None,
    count_fn: Optional[CountFn] = None,
    sampling: str = "permutation",
    baseline: str = "none",
    stop_when_positive: bool = False,
    perm: Optional[jnp.ndarray] = None,
    perm_idx: Optional[jnp.ndarray] = None,
    perm_w: Optional[jnp.ndarray] = None,
    free_rounds=0,
    free_lo=0,
    init_sums: Optional[jnp.ndarray] = None,
    init_sqsums: Optional[jnp.ndarray] = None,
    init_rounds=0,
    aux_init: Any = None,
    n_ref_eff=None,
    log_term=None,
) -> SearchResult:
    """Run one best-arm identification (one BUILD assignment or one SWAP pick).

    Args:
      stats_fn: ``(ref_idx[B], w[B], lead, rnd) -> (sums, sqsums, cross)``
        — per-arm weighted batch sums of ``g``, ``g²`` and ``g·g_lead``
        over the sampled reference points (weights are the {0,1} padding
        mask; ``lead`` is an arm index, only meaningful when ≥ 0; ``rnd``
        is the round index, letting the caller serve cached distance
        columns for warm rounds).
      aux_init: optional caller state threaded through the search carry.
        When given, ``stats_fn`` takes a fifth argument (the current aux)
        and returns it, possibly updated, as a fourth output:
        ``(ref_idx, w, lead, rnd, aux) -> (sums, sqsums, cross, aux)``.
        This is how the device-resident PIC cache achieves write-through:
        the ``[n, width]`` column buffer plus its high-water round count
        ride the ``while_loop`` carry, and each fresh round's distance
        block is stored from inside the loop — the recompute that a
        host-side cache materialisation would pay is gone.  The final aux
        is returned as ``SearchResult.aux``.
      perm / free_rounds: paper App 2.2 cache — reuse a FIXED reference
        permutation across calls; rounds in ``[free_lo, free_rounds)``
        (Python ints or traced int32 scalars) hit the caller's distance
        cache and cost zero *new* evaluations (they are tallied in
        ``n_evals_cached`` instead).  ``free_lo > 0`` is the bounded-width
        PIC cache (``repro.core.pic_cache``): rounds below the resident
        window were recycled, so the caller recomputes them — they count
        as fresh again.
      perm_idx / perm_w: explicit pre-tiled permutation layout (position
        index and {0,1} validity weight per reference slot), overriding
        the cyclic tiling of ``perm``.  This is how the sharded driver
        runs permutation sampling over per-shard stratified permutations:
        round ``r`` occupies slots ``[r·B, (r+1)·B)`` with shard ``s``
        owning the ``[s·b_loc, (s+1)·b_loc)`` sub-slice.  The layout must
        cover every reference point exactly once among its weight-1 slots
        (``Σ perm_w == n_ref``) so the budget exhausts exactly.
      init_sums / init_sqsums / init_rounds: BanditPAM++ permutation-
        invariant caching (PIC).  Seed the search with per-arm Σg / Σg²
        already accumulated over the first ``init_rounds`` batches of the
        SAME fixed ``perm`` by a previous call (the caller must have
        re-validated them against the current g — see
        ``banditpam._carry_delta``).  The loop resumes at round
        ``init_rounds`` with ``n_used = min(init_rounds·B, n_ref)``; per-arm
        σ is re-derived from the carried moments (a strictly better estimate
        than the paper's first-batch Eq. 11, with the same union-bound
        validity since σ is treated as a known scale).  Requires
        ``sampling="permutation"`` and an explicit ``perm``.
      exact_fn: ``() -> mu[n_arms]`` exact objective; only used by the
        ``"replacement"`` fallback.
      count_fn: distance evaluations *per reference point* as a function of
        the survivor mask (BUILD: #active arms; SWAP: #distinct active
        non-medoids, since FastPAM1 shares distances across the k medoids).
      n_ref_eff: optional TRACED effective reference count ≤ ``n_ref``.
        ``n_ref`` keeps sizing every shape (perm tiling, arm arrays) while
        ``n_ref_eff`` drives every *value* use — the budget condition, the
        finite-population CI factor, and the exact-fallback accounting.
        This is what lets one compiled search serve a batch of padded
        fits with ragged per-fit n (``repro.core.banditpam.fit_batch``):
        shapes are padded to the batch maximum, the per-fit logical n
        rides in as data.  Defaults to ``n_ref`` (the historical static
        behavior, bit-identical).
      log_term: optional traced ``log(1/δ)`` override.  ``delta`` is a
        static trace constant; a batch of fits with ragged n has per-fit
        δ = 1/(1000·n_i), so the batched driver passes the log-term as
        data instead.  Mutually redundant with ``delta`` — when given,
        ``delta`` is ignored.
    """
    if sampling not in ("permutation", "replacement"):
        raise ValueError(f"unknown sampling mode {sampling!r}")
    if baseline not in ("none", "leader"):
        raise ValueError(f"unknown baseline mode {baseline!r}")
    if init_sums is not None and (
            sampling != "permutation" or (perm is None and perm_idx is None)):
        raise ValueError("carried statistics require permutation sampling "
                         "over an explicit fixed perm (PIC invariant)")
    if (perm_idx is None) != (perm_w is None):
        raise ValueError("perm_idx and perm_w must be given together")
    if delta is None:
        delta = 1.0 / (1000.0 * n_arms)
    if count_fn is None:
        count_fn = _default_count
    if log_term is None:
        log_term = jnp.float32(jnp.log(1.0 / delta))
    else:
        log_term = jnp.asarray(log_term, jnp.float32)
    n_eff = n_ref if n_ref_eff is None else n_ref_eff
    B = int(batch_size)
    use_perm = sampling == "permutation"
    use_lead = baseline == "leader"

    active0 = jnp.ones((n_arms,), jnp.bool_) if active_init is None else active_init

    n_rounds_max = -(-n_ref // B)
    if use_perm and perm_idx is None:
        if perm is None:
            key, pkey = jax.random.split(key)
            perm = jax.random.permutation(pkey, n_ref).astype(jnp.int32)
        total = n_rounds_max * B
        reps = -(-total // n_ref)
        perm_idx = jnp.tile(perm, reps)[:total]
        perm_w = (jnp.arange(total) < n_ref).astype(jnp.float32)

    def cond(s: _State) -> jnp.ndarray:
        go = jnp.logical_and(s.n_used < n_eff,
                             jnp.sum(s.active.astype(jnp.int32)) > 1)
        if stop_when_positive:
            # SWAP-convergence shortcut (beyond-paper, EXPERIMENTS §Perf):
            # the driver only *uses* the winner if its mean is negative
            # (a loss-improving swap).  Once every surviving arm's LCB is
            # positive, no arm can be an improving swap w.p. ≥ 1−δ, so
            # identifying the argmin among them is wasted sampling.
            n_used_f = jnp.maximum(s.n_used.astype(jnp.float32), 1.0)
            mu = s.sums / n_used_f
            ci = s.sigma * jnp.sqrt(log_term / n_used_f)
            lcb_min = jnp.min(jnp.where(s.active, mu - ci, jnp.inf))
            go = jnp.logical_and(go, lcb_min <= 0.0)
        return go

    def body(s: _State) -> _State:
        if use_perm:
            start = s.rounds * B
            ref_idx = jax.lax.dynamic_slice(perm_idx, (start,), (B,))
            w = jax.lax.dynamic_slice(perm_w, (start,), (B,))
            key = s.key
        else:
            key, sub = jax.random.split(s.key)
            ref_idx = jax.random.randint(sub, (B,), 0, n_ref)
            w = jnp.ones((B,), jnp.float32)
        b_eff = jnp.sum(w).astype(jnp.int32)
        b_eff_f = b_eff.astype(jnp.float32)
        if aux_init is None:
            sums_b, sq_b, cross_b = stats_fn(ref_idx, w,
                                             jnp.maximum(s.lead, 0), s.rounds)
            aux = s.aux
        else:
            sums_b, sq_b, cross_b, aux = stats_fn(
                ref_idx, w, jnp.maximum(s.lead, 0), s.rounds, s.aux)

        # ---- raw statistics (paper) ----
        sums = s.sums + sums_b
        sqsums = s.sqsums + sq_b
        n_new = s.n_used + b_eff
        n_new_f = n_new.astype(jnp.float32)
        mu_hat = sums / n_new_f
        batch_mean = sums_b / b_eff_f
        batch_var = jnp.maximum(sq_b / b_eff_f - batch_mean * batch_mean, 0.0)
        sigma = jnp.where(s.n_used == 0,                      # Eq. 11
                          jnp.sqrt(batch_var) + SIGMA_FLOOR, s.sigma)
        fpc = (jnp.sqrt(jnp.maximum(1.0 - n_new_f / n_eff, 0.0))
               if use_perm else jnp.float32(1.0))
        ci = sigma * jnp.sqrt(log_term / n_new_f) * fpc
        ucb = jnp.where(s.active, mu_hat + ci, jnp.inf)
        lcb = mu_hat - ci
        kill_raw = lcb > jnp.min(ucb)

        # ---- differenced statistics vs the pilot leader (beyond-paper) ----
        if use_lead:
            have_lead = s.lead >= 0
            d_b = sums_b - sums_b[jnp.maximum(s.lead, 0)]
            dsq_b = sq_b - 2.0 * cross_b + sq_b[jnp.maximum(s.lead, 0)]
            d_sums = s.d_sums + jnp.where(have_lead, d_b, 0.0)
            d_sq = s.d_sq + jnp.where(have_lead, dsq_b, 0.0)
            n_post = s.n_post + jnp.where(have_lead, b_eff, 0)
            n_post_f = jnp.maximum(n_post.astype(jnp.float32), 1.0)
            first_d = jnp.logical_and(have_lead, s.n_post == 0)
            dvar = jnp.maximum(dsq_b / b_eff_f - (d_b / b_eff_f) ** 2, 0.0)
            sigma_d = jnp.where(first_d, jnp.sqrt(dvar) + SIGMA_FLOOR, s.sigma_d)
            mu_d = d_sums / n_post_f
            ci_d = sigma_d * jnp.sqrt(log_term / n_post_f)
            ucb_d = jnp.where(s.active, mu_d + ci_d, jnp.inf)
            # Deterministic fp-tie break (see LEAD_TIE_REL): the margin
            # must clear a sliver of the arm's RAW confidence width, and
            # the leader is excluded from its own elimination test — its
            # differenced margin is structurally an exact-zero tie.
            eps_d = LEAD_TIE_REL * sigma * jnp.sqrt(log_term / n_post_f)
            kill_d = jnp.logical_and(
                n_post > 0, (mu_d - ci_d) > jnp.min(ucb_d) + eps_d)
            kill_d = jnp.logical_and(kill_d, jnp.arange(n_arms) != s.lead)
            kill = jnp.logical_or(kill_raw, kill_d)
            # pilot leader: fixed after the first round
            lead = jnp.where(s.lead >= 0, s.lead,
                             jnp.argmin(jnp.where(s.active, mu_hat, jnp.inf)
                                        ).astype(jnp.int32))
        else:
            kill = kill_raw
            lead = s.lead
            d_sums, d_sq, sigma_d, n_post = s.d_sums, s.d_sq, s.sigma_d, s.n_post

        active = jnp.logical_and(s.active, jnp.logical_not(kill))
        # Cache-served rounds are [free_lo, free_rounds); rounds below the
        # resident window (recycled slots) are fresh recomputations.
        fresh = jnp.logical_or(s.rounds >= free_rounds,
                               s.rounds < free_lo).astype(jnp.uint32)
        cost = count_fn(s.active) * b_eff.astype(jnp.uint32)
        n_evals = s.n_evals + fresh * cost
        n_cached = s.n_cached + (1 - fresh) * cost
        return _State(key, sums, sqsums, sigma, active, n_new, lead,
                      d_sums, d_sq, sigma_d, n_post, n_evals, n_cached,
                      s.rounds + 1, aux)

    zeros = jnp.zeros((n_arms,), jnp.float32)
    if init_sums is not None:
        # PIC seed: resume from the carried permutation prefix.  σ comes
        # from the carried moments (all arms share the same sample count).
        # The consumed count is Σ perm_w over the prefix — NOT rounds·B:
        # stratified sharded layouts scatter weight-0 padding into early
        # rounds, and an inflated n_used would both tighten the seeded
        # CIs beyond the δ guarantee and exhaust the budget before the
        # permutation is actually consumed.  (For the cyclic single-
        # device tiling this reduces to min(rounds·B, n_ref) exactly.)
        rounds0 = jnp.asarray(init_rounds, jnp.int32)
        n_used0 = jnp.sum(
            perm_w * (jnp.arange(perm_w.shape[0]) < rounds0 * B)
        ).astype(jnp.int32)
        n0_f = jnp.maximum(n_used0.astype(jnp.float32), 1.0)
        mu0 = init_sums / n0_f
        var0 = jnp.maximum(init_sqsums / n0_f - mu0 * mu0, 0.0)
        sums0, sqsums0 = init_sums, init_sqsums
        sigma0 = jnp.sqrt(var0) + SIGMA_FLOOR
    else:
        rounds0 = jnp.int32(0)
        n_used0 = jnp.int32(0)
        sums0, sqsums0 = zeros, zeros
        sigma0 = jnp.full((n_arms,), jnp.inf, jnp.float32)
    init = _State(
        key=key, sums=sums0, sqsums=sqsums0, sigma=sigma0,
        active=active0, n_used=n_used0, lead=jnp.int32(-1),
        d_sums=zeros, d_sq=zeros,
        sigma_d=jnp.full((n_arms,), jnp.inf, jnp.float32),
        n_post=jnp.int32(0), n_evals=jnp.uint32(0), n_cached=jnp.uint32(0),
        rounds=rounds0, aux=() if aux_init is None else aux_init,
    )
    final = jax.lax.while_loop(cond, body, init)

    n_survivors = jnp.sum(final.active.astype(jnp.int32))
    mu_final = final.sums / jnp.maximum(final.n_used.astype(jnp.float32), 1.0)

    def exact_branch(_):
        mu_exact = exact_fn()
        mu_sel = jnp.where(final.active, mu_exact, jnp.inf)
        best = jnp.argmin(mu_sel).astype(jnp.int32)
        extra = count_fn(final.active) * jnp.asarray(n_eff).astype(jnp.uint32)
        return best, mu_sel[best], final.n_evals + extra, jnp.bool_(True)

    def sampled_branch(_):
        # In permutation mode a full budget means mu_hat is the exact mean,
        # so ties are resolved by lowest index — identical to PAM's argmin.
        mu_sel = jnp.where(final.active, mu_final, jnp.inf)
        best = jnp.argmin(mu_sel).astype(jnp.int32)
        return best, mu_sel[best], final.n_evals, jnp.bool_(False)

    if use_perm:
        best, mu_best, n_evals, used_exact = sampled_branch(None)
    else:
        best, mu_best, n_evals, used_exact = jax.lax.cond(
            n_survivors > 1, exact_branch, sampled_branch, operand=None)

    return SearchResult(best=best, mu_best=mu_best, n_evals=n_evals,
                        rounds=final.rounds, used_exact=used_exact,
                        n_survivors=n_survivors,
                        n_evals_cached=final.n_cached,
                        sums=final.sums, sqsums=final.sqsums,
                        aux=final.aux)
