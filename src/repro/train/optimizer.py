"""AdamW with configurable moment dtype.

The 480B MoE config stores first/second moments in bf16 (docs/design.md
§Memory-fit) — update math still runs in f32 (moments are upcast, the
new moments rounded back), so the quality cost is rounding, not range.
No optax dependency: the whole optimizer is a pytree + two functions,
which keeps checkpointing and ZeRO-style sharding trivial.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: OptConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state, cfg: OptConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, opt_state["step"])
    bc1 = 1.0 - cfg.b1 ** step.astype(F32)
    bc2 = 1.0 - cfg.b2 ** step.astype(F32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(F32) * scale
        m32 = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * g32 * g32
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:                       # decoupled wd on matrices only
            u = u + cfg.weight_decay * p.astype(F32)
        p_new = (p.astype(F32) - lr * u).astype(p.dtype)
        return p_new, m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {"m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
