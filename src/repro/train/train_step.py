"""Loss + train-step factory: microbatched gradient accumulation, remat'd
layer groups (inside the model), optional int8 error-feedback compression
of the cross-pod gradient all-reduce.

The returned step is a single jit-able pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)``; all
distribution comes from shardings on its inputs/outputs plus the logical
constraints inside the model.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from .optimizer import OptConfig, apply_updates

F32 = jnp.float32
AUX_WEIGHT = 0.01


def lm_loss(cfg: ArchConfig, logits: jnp.ndarray, labels: jnp.ndarray,
            mask: jnp.ndarray) -> jnp.ndarray:
    """Next-token CE in f32; audio: mean over codebooks ([..., nc, V])."""
    logits = logits.astype(F32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if cfg.frontend == "audio_stub":
        nll = nll.mean(-1)                         # [B, L, nc] -> [B, L]
    mask = mask.astype(F32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(cfg: ArchConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = M.forward(cfg, params, batch)
    ce = lm_loss(cfg, logits, batch["labels"], batch["loss_mask"])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig, microbatches: int = 1):
    """Build the jit-able train step with gradient accumulation."""

    grad_fn = jax.value_and_grad(functools.partial(loss_fn, cfg), has_aux=True)

    def split_mb(batch):
        def r(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            mbs = split_mb(batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (g_sum, l_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            aux = {"ce": loss, "aux": jnp.float32(0.0)}
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        loss, aux = loss_fn(cfg, params, batch)
        return {"loss": loss, **aux}
    return eval_step
