"""Cross-pod compressed training step: the production wiring of
``repro.distributed.compression`` (int8 error-feedback gradient reduction
on the pod axis only).

Layout: params replicated across "pod" (sharded over "model"/"data" as
usual — those axes stay GSPMD-auto inside the shard_map); each pod
computes its gradient on its slice of the global batch in full precision;
the POD-axis leg of the reduction is int8-EF-compressed (4x fewer
cross-DCN bytes than an f32 ring all-reduce); residuals are carried per
pod in the training state (shape [n_pods, ...] per leaf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.compression import tree_psum_int8_ef
from .optimizer import OptConfig, apply_updates
from .train_step import loss_fn

F32 = jnp.float32


def init_pod_residuals(params, n_pods: int):
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, F32), params)


def make_compressed_train_step(cfg: ArchConfig, opt_cfg: OptConfig,
                               mesh: Mesh):
    """(params, opt_state, residuals, batch) -> (params, opt, residuals,
    metrics); batch's leading axis must divide by the pod extent."""
    assert "pod" in mesh.axis_names, "compressed step needs a pod axis"
    n_pods = int(mesh.shape["pod"])
    grad_fn = jax.value_and_grad(functools.partial(loss_fn, cfg),
                                 has_aux=True)

    def per_pod(params, batch, residuals):
        # params replicated over pod; batch pod-sharded (leading axis);
        # residuals pod-local (leading axis 1 inside).
        (loss, _), grads = grad_fn(params, batch)
        res_local = jax.tree.map(lambda r: r[0], residuals)
        gsum, new_res = tree_psum_int8_ef(grads, res_local, "pod")
        gavg = jax.tree.map(lambda g: g / n_pods, gsum)
        loss_avg = jax.lax.pmean(loss, "pod")
        new_res = jax.tree.map(lambda r: r[None], new_res)
        return loss_avg, gavg, new_res

    smap = jax.shard_map(
        per_pod, mesh=mesh, axis_names={"pod"},
        in_specs=(P(), P("pod"), P("pod")),
        out_specs=(P(), P(), P("pod")),
        check_vma=False)

    def train_step(params, opt_state, residuals, batch):
        loss, grads, residuals = smap(params, batch, residuals)
        params, opt_state, om = apply_updates(params, grads, opt_state,
                                              opt_cfg)
        return params, opt_state, residuals, {"loss": loss, **om}

    return train_step
