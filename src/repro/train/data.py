"""Deterministic, resumable synthetic LM data pipeline.

Every batch is a pure function of ``(seed, step)`` — the keystone of the
fault-tolerance story (docs/design.md §6): any host can recompute any step's
shard after a failure, checkpoints only need to record the step counter,
and elastic re-sharding needs no pipeline state migration.

Token streams follow a noisy affine recurrence, giving a learnable
structure (a model that captures the bigram dynamics drops well below the
uniform-entropy loss floor).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    noise: float = 0.1         # fraction of uniformly-resampled tokens
    mult: int = 31             # affine recurrence multiplier


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, step: int,
                    dcfg: DataConfig = DataConfig()) -> Dict[str, jnp.ndarray]:
    """Batch for `step`, identical no matter which host computes it."""
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    v = cfg.vocab
    nc = cfg.n_codebooks if cfg.frontend == "audio_stub" else 1
    x0 = jax.random.randint(k1, (batch, 1, nc), 0, v)

    def gen(carry, k):
        nxt = (carry * dcfg.mult + 7) % v
        return nxt, nxt

    _, toks = jax.lax.scan(gen, x0, jnp.arange(seq - 1))
    toks = jnp.concatenate([x0[None], toks], 0)          # [L, B, 1, nc]
    toks = jnp.moveaxis(toks[:, :, 0, :], 0, 1)          # [B, L, nc]
    noise_mask = jax.random.uniform(k2, toks.shape) < dcfg.noise
    toks = jnp.where(noise_mask, jax.random.randint(k3, toks.shape, 0, v), toks)

    labels = jnp.roll(toks, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)

    if cfg.frontend == "audio_stub":
        return {"tokens": toks, "labels": labels, "loss_mask": mask}
    toks, labels = toks[..., 0], labels[..., 0]
    out = {"tokens": toks, "labels": labels, "loss_mask": mask}
    if cfg.frontend == "vision_stub":
        p = cfg.n_patches
        out["tokens"] = toks[:, : seq - p]
        out["patch_emb"] = jax.random.normal(k4, (batch, p, cfg.d_model),
                                             jnp.float32)
        # labels cover the full (patch + text) sequence; no loss on patches
        out["labels"] = jnp.concatenate(
            [jnp.zeros((batch, p), labels.dtype), labels[:, : seq - p]], 1)
        out["loss_mask"] = jnp.concatenate(
            [jnp.zeros((batch, p), jnp.float32), mask[:, : seq - p]], 1)
    return out


class DataPipeline:
    """Stateful iterator facade over the stateless generator (checkpoints
    store just `step`)."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int,
                 dcfg: DataConfig = DataConfig(), start_step: int = 0):
        self.cfg, self.batch, self.seq, self.dcfg = cfg, batch, seq, dcfg
        self.step = start_step

    def __next__(self):
        b = synthetic_batch(self.cfg, self.batch, self.seq, self.step, self.dcfg)
        self.step += 1
        return b

    def state(self) -> Dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    @classmethod
    def from_state(cls, cfg, batch, seq, state: Dict) -> "DataPipeline":
        return cls(cfg, batch, seq, DataConfig(seed=state["seed"]),
                   start_step=state["step"])
