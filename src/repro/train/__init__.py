from .data import DataConfig, DataPipeline, synthetic_batch
from .optimizer import OptConfig, apply_updates, init_opt_state
from .train_step import lm_loss, loss_fn, make_eval_step, make_train_step

__all__ = ["DataConfig", "DataPipeline", "synthetic_batch", "OptConfig",
           "apply_updates", "init_opt_state", "lm_loss", "loss_fn",
           "make_eval_step", "make_train_step"]
