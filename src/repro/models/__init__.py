from . import layers, model, moe, ssm

__all__ = ["layers", "model", "moe", "ssm"]
