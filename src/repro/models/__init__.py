from . import layers, model, moe, ssm
