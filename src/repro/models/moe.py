"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

TPU/SPMD design (hillclimbed — see EXPERIMENTS.md §Perf/arctic):

* Dispatch is **per-data-shard local**: tokens are viewed as
  [n_data_shards, T_loc, d] (the shard count is static at trace time from
  the active mesh), and the sort/bucket/scatter runs vmapped per shard with
  per-shard capacity C_loc = ceil(T_loc·k·cf/E).  Nothing crosses shards.
* The only cross-device traffic is the expert-axis reshard of the dispatch
  buffer [E, shards, C_loc, d] from data-sharded to expert(model)-sharded —
  which is exactly the canonical MoE all-to-all — and its inverse after the
  expert FFN.  (A naive global scatter into an expert-sharded buffer makes
  GSPMD all-reduce the whole buffer across every device: ~1600x more bytes;
  measured in EXPERIMENTS.md.)
* Dispatch avoids the O(T·E·C) one-hot tensor of GShard: assignments are
  sorted by expert (stable), position-in-expert comes from sorted segment
  offsets, and tokens beyond capacity are dropped (Switch semantics).

Supports arctic's parallel dense-residual MLP and llama4's always-on
shared expert via the caller.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import get_mesh, shard

F32 = jnp.float32


def init_moe(key, d: int, ff: int, n_experts: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "router": (jax.random.normal(k1, (d, n_experts)) * s).astype(F32),
        "wi": (jax.random.normal(k2, (n_experts, d, ff)) * s).astype(dtype),
        "wg": (jax.random.normal(k3, (n_experts, d, ff)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (n_experts, ff, d)) * ff ** -0.5).astype(dtype),
    }


def capacity(t: int, k: int, e: int, cf: float) -> int:
    c = int(-(-t * k * cf // e))
    return max(8, -(-c // 8) * 8)     # pad to a multiple of 8 lanes


def _n_data_shards() -> int:
    mesh = get_mesh()
    if mesh is None:
        return 1
    out = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            out *= int(mesh.shape[a])
    return out


def moe_layer(p: dict, x: jnp.ndarray, *, top_k: int, capacity_factor: float
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, L, d] -> (y [B, L, d], aux_loss scalar)."""
    b, l, d = x.shape
    e = p["router"].shape[1]
    t = b * l
    ns = _n_data_shards()
    if t % ns:                      # tiny inputs on a big mesh: fall back
        ns = 1
    t_loc = t // ns
    c_loc = capacity(t_loc, top_k, e, capacity_factor)
    xt = x.reshape(t, d)

    logits = (xt.astype(F32) @ p["router"])                   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)                  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- per-shard sort-based dispatch (shard-local by construction) ----
    def local_dispatch(xt_l, eidx_l):
        flat_e = eidx_l.reshape(-1)                           # [T_loc*k]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        tok = order // top_k
        seg = jnp.searchsorted(sorted_e, jnp.arange(e))
        pos = jnp.arange(t_loc * top_k) - seg[sorted_e]
        keep = pos < c_loc
        slot = jnp.where(keep, sorted_e * c_loc + pos, e * c_loc)
        buf = jnp.zeros((e * c_loc + 1, d), x.dtype).at[slot].set(xt_l[tok])
        return buf[:-1].reshape(e, c_loc, d), order, keep, slot

    xt_s = shard(xt.reshape(ns, t_loc, d), "batch", None, None)
    eidx_s = eidx.reshape(ns, t_loc, top_k)
    bufs, orders, keeps, slots = jax.vmap(local_dispatch)(xt_s, eidx_s)

    # ---- expert FFN: the E-axis reshard below is the MoE all-to-all ----
    h = jnp.moveaxis(bufs, 1, 0)                              # [E, ns, C_loc, d]
    h = shard(h, "experts", "batch", None, None)
    act = jax.nn.silu(jnp.einsum("encd,edf->encf", h, p["wg"])) \
        * jnp.einsum("encd,edf->encf", h, p["wi"])
    act = shard(act, "experts", "batch", None, None)
    out = jnp.einsum("encf,efd->encd", act, p["wo"])
    # Keep the combine einsum expert-sharded (weights stay EP-local) and
    # only THEN reshard the small output — without the intermediate
    # constraint GSPMD may satisfy the replicated output by all-gathering
    # the [E, ff, d] WEIGHTS instead (measured 2.6 GB/layer on the
    # long-context decode cell; EXPERIMENTS §Perf track 1b).
    out = shard(out, "experts", "batch", None, None)
    out = shard(out, None, "batch", None, None)               # a2a back
    out = jnp.moveaxis(out, 0, 1)                             # [ns, E, C_loc, d]

    # ---- per-shard combine ----
    def local_combine(out_l, order, keep, slot, gate_l):
        flat = out_l.reshape(e * c_loc, d)
        gathered = flat[jnp.minimum(slot, e * c_loc - 1)] * keep[:, None].astype(x.dtype)
        wsel = gate_l.reshape(-1)[order][:, None].astype(x.dtype)
        tok = order // top_k
        return jnp.zeros((t_loc, d), x.dtype).at[tok].add(gathered * wsel)

    gate_s = gate.reshape(ns, t_loc, top_k)
    y = jax.vmap(local_combine)(out, orders, keeps, slots, gate_s)
    y = shard(y, "batch", None, None).reshape(t, d)

    # ---- load-balancing aux loss (Switch) ----
    me = probs.mean(0)                                        # [E]
    ce = jnp.zeros((e,), F32).at[eidx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, l, d), aux
