"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention
(global / sliding-window "local" / llama4-style "chunked"), SwiGLU MLP.

Attention is a chunked online-softmax ("flash"-style) implementation:

* prefill/train: an outer *static* loop over query chunks; for each query
  chunk only the kv chunks its mask can reach are scanned (causal
  triangle, sliding window, or chunk-diagonal), so HLO FLOPs match the
  true masked FLOPs — no 2x causal waste, and local layers do O(L·W) not
  O(L²).  The [Cq, Ck] score tile lives only inside the scan body.
* decode: single-position path against a (possibly rolling) KV cache with
  explicit absolute-position masking.

GQA is expressed by broadcasting kv heads to q heads inside the einsum
(`kv_heads < model-axis extent` makes kv replication the right TP layout;
q heads shard over "model").
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

F32 = jnp.float32
NEG_INF = -1e30

# When True the kv-chunk loop unrolls to a python loop instead of lax.scan.
# Same math/HLO-ops; used by the dry-run so cost_analysis (which counts a
# scan body once, not x trip-count) sees the true FLOPs.
UNROLL_KV = False


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(F32)
    n = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (n * w.astype(F32)).astype(x.dtype)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., L, H, Dh]; pos: [L] absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    ang = pos.astype(F32)[:, None] * freqs[None, :]          # [L, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def _mask(kind: str, window: int, qpos: jnp.ndarray, kpos: jnp.ndarray
          ) -> jnp.ndarray:
    """[Cq, Ck] boolean admissibility mask for absolute positions."""
    q = qpos[:, None]
    k = kpos[None, :]
    m = k <= q                                    # causal
    if kind == "local":
        m &= k > q - window
    elif kind == "chunked":
        m &= (k // window) == (q // window)
    return m


def _kv_range(kind: str, window: int, qo: int, cq: int, ck: int, lk: int
              ) -> Tuple[int, int]:
    """Static kv-chunk index range [j0, j1) reachable from q chunk at qo."""
    hi = min(lk, qo + cq)                         # causal upper bound
    if kind == "global":
        lo = 0
    elif kind == "local":
        lo = max(0, qo - window + 1)
    elif kind == "chunked":
        lo = (qo // window) * window
    else:
        raise ValueError(kind)
    return lo // ck, -(-hi // ck)


def _sdpa_chunk(q, k, v, m, l, acc, mask):
    """One online-softmax accumulation step.

    q: [B, H, Cq, Dh]; k, v: [B, H, Ck, Dh]; mask: [Cq, Ck];
    m, l: [B, H, Cq]; acc: [B, H, Cq, Dh] (f32).
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=F32) * scale
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, -1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, -1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=F32)
    return m_new, l_new, acc_new


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, kind: str,
              window: int, q_chunk: int = 2048, kv_chunk: int = 2048
              ) -> jnp.ndarray:
    """Self-attention for prefill/train (Lq == Lk, q offset 0).

    q: [B, L, H, Dh]; k, v: [B, L, KVH, Dh] -> [B, L, H, Dh].
    """
    b, lq, h, dh = q.shape
    lk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    if g > 1:   # broadcast kv heads to q heads (TP: kv replicated)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qh = jnp.moveaxis(q, 2, 1)            # [B, H, L, Dh]
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)

    cq = min(q_chunk, lq)
    ck = min(kv_chunk, lk)
    assert lq % cq == 0 and lk % ck == 0, (lq, cq, lk, ck)

    outs = []
    for qi in range(lq // cq):
        qo = qi * cq
        qblk = qh[:, :, qo:qo + cq]
        j0, j1 = _kv_range(kind, window, qo, cq, ck, lk)
        qpos = qo + jnp.arange(cq)
        m0 = jnp.full((b, h, cq), NEG_INF, F32)
        l0 = jnp.zeros((b, h, cq), F32)
        a0 = jnp.zeros((b, h, cq, dh), F32)
        if UNROLL_KV:
            m, l, acc = m0, l0, a0
            for j in range(j0, j1):
                kc = kh[:, :, j * ck:(j + 1) * ck]
                vc = vh[:, :, j * ck:(j + 1) * ck]
                kpos = j * ck + jnp.arange(ck)
                msk = _mask(kind, window, qpos, kpos)
                m, l, acc = _sdpa_chunk(qblk, kc, vc, m, l, acc, msk)
        else:
            kv_js = jnp.arange(j0, j1)
            ks = kh[:, :, j0 * ck:j1 * ck].reshape(b, h, j1 - j0, ck, dh)
            vs = vh[:, :, j0 * ck:j1 * ck].reshape(b, h, j1 - j0, ck, dh)

            def body(carry, xs):
                m, l, acc = carry
                j, kc, vc = xs
                kpos = j * ck + jnp.arange(ck)
                msk = _mask(kind, window, qpos, kpos)
                m, l, acc = _sdpa_chunk(qblk, kc, vc, m, l, acc, msk)
                return (m, l, acc), None

            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0),
                (kv_js, jnp.moveaxis(ks, 2, 0), jnp.moveaxis(vs, 2, 0)))
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))
    out = jnp.concatenate(outs, axis=2)                    # [B, H, L, Dh]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     entry_pos: jnp.ndarray, pos: jnp.ndarray, *, kind: str,
                     window: int) -> jnp.ndarray:
    """Single-token attention against a cache.

    q: [B, 1, H, Dh]; caches: [B, S_cache, KVH, Dh]; entry_pos: [B, S_cache]
    absolute positions of cache entries (−1 = empty); pos: [] current
    absolute position of the query token.
    """
    b, _, h, dh = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q[:, 0].reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32),
                   k_cache.astype(F32)) * dh ** -0.5
    valid = (entry_pos >= 0) & (entry_pos <= pos)
    if kind == "local":
        valid &= entry_pos > pos - window
    elif kind == "chunked":
        valid &= (entry_pos // window) == (pos // window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(F32))
    return o.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + norm) and MLP
# ---------------------------------------------------------------------------

def init_attn(key, d_in: int, n_heads: int, n_kv: int, hd: int, d_out: int,
              qk_norm: bool, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_in ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d_in, n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d_in, n_kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d_in, n_kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * hd, d_out))
               * (n_heads * hd) ** -0.5).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attn_qkv(p: dict, x: jnp.ndarray, pos: jnp.ndarray, *, n_heads: int,
             n_kv: int, hd: int, theta: float, qk_norm: bool):
    b, l, _ = x.shape
    q = (x @ p["wq"]).reshape(b, l, n_heads, hd)
    k = (x @ p["wk"]).reshape(b, l, n_kv, hd)
    v = (x @ p["wv"]).reshape(b, l, n_kv, hd)
    if qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def init_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "wg": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dtype),
        "wo": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = shard(h, "batch", "seq", "ff")
    return h @ p["wo"]
