"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

TPU adaptation (docs/design.md §3): the recurrences are *not* lowered as
length-L sequential loops.

* Mamba-1: `h_t = dA_t h_{t-1} + dBx_t` runs as a `jax.lax.associative_scan`
  over the sequence axis — log-depth, fully vectorized on the VPU.
* Mamba-2: the SSD chunked form — intra-chunk attention-like matmuls
  (MXU-shaped [T, T] x [T, hd]) plus an inter-chunk state scan of length
  L/T.  Scalar-per-head decay makes the chunk math exact.

Decode is the O(1) recurrent step carrying (conv_state, ssm_state).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

F32 = jnp.float32


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d; x [B, L, C], w [K, C], b [C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg, dtype) -> dict:
    d, di, st, dtr, k = cfg.d_model, cfg.di, cfg.ssm_state, cfg.dtr, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_x": (jax.random.normal(keys[0], (d, di)) * s).astype(dtype),
        "in_z": (jax.random.normal(keys[5], (d, di)) * s).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (k, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": (jax.random.normal(keys[2], (di, dtr + 2 * st)) * di ** -0.5).astype(dtype),
        "dt_proj": (jax.random.normal(keys[3], (dtr, di)) * dtr ** -0.5).astype(dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),     # softplus ~ 0.12 init
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=F32)[None, :], (di, 1))),
        "D": jnp.ones((di,), F32),
        "out_proj": (jax.random.normal(keys[4], (di, d)) * di ** -0.5).astype(dtype),
    }


def _mamba1_inner(p, xc, dt, Bm, Cm, h0=None, scan_dtype=F32):
    """Shared selective-scan math.

    xc [B,L,di] (post conv+silu), dt [B,L,di], Bm/Cm [B,L,st].
    Returns (y [B,L,di], h_last [B,di,st]).

    The associative scan's [B, L, di, state] operands dominate the whole
    block's HBM traffic (log2 L passes over them).  ``scan_dtype=bf16``
    halves the scan operands; training numerics are indistinguishable
    (rel. loss diff ~2e-5 over 10 steps on the reduced config), BUT the
    dry-run's operand-sum byte metric showed NO win (the inserted convert
    ops offset the savings; the metric cannot see TPU fusion), so f32
    stays the measured-default.  EXPERIMENTS §Perf/falcon records the
    refuted iteration.
    """
    A = -jnp.exp(p["A_log"].astype(F32))                       # [di, st]
    dA = jnp.exp(dt[..., None] * A[None, None])                # [B,L,di,st]
    dBx = (dt * xc)[..., None] * Bm[:, :, None, :]             # [B,L,di,st]
    if h0 is not None:
        # fold the incoming state into the first step
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
    def combine(a, b):
        return (a[0] * b[0], b[0] * a[1] + b[1])
    _, h = jax.lax.associative_scan(
        combine, (dA.astype(scan_dtype), dBx.astype(scan_dtype)), axis=1)
    y = jnp.einsum("blds,bls->bld", h, Cm.astype(scan_dtype),
                   preferred_element_type=F32)
    return y, h[:, -1].astype(F32)


def mamba1(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence Mamba-1 block (train/prefill). x: [B, L, d]."""
    xin, z = x @ p["in_x"], x @ p["in_z"]
    xin = shard(xin, "batch", "seq", "d_inner")
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    proj = xc @ p["x_proj"]
    dtr = p["dt_proj"].shape[0]
    st = (proj.shape[-1] - dtr) // 2
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(F32)
                         + p["dt_bias"].astype(F32))
    y, _ = _mamba1_inner(p, xc.astype(F32), dt, Bm.astype(F32), Cm.astype(F32))
    y = y + p["D"][None, None] * xc.astype(F32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_prefill(p: dict, x: jnp.ndarray):
    """Full-sequence forward that also returns the decode state."""
    xin, z = x @ p["in_x"], x @ p["in_z"]
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    proj = xc @ p["x_proj"]
    dtr = p["dt_proj"].shape[0]
    st = (proj.shape[-1] - dtr) // 2
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(F32)
                         + p["dt_bias"].astype(F32))
    y, h_last = _mamba1_inner(p, xc.astype(F32), dt, Bm.astype(F32),
                              Cm.astype(F32))
    y = y + p["D"][None, None] * xc.astype(F32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    k = p["conv_w"].shape[0]
    conv_tail = xin[:, -(k - 1):, :]
    return y @ p["out_proj"], (conv_tail, h_last)


def mamba1_decode(p: dict, x: jnp.ndarray, state: Tuple[jnp.ndarray, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token step. x: [B, 1, d]; state = (conv [B, K-1, di], h [B, di, st])."""
    conv_st, h = state
    xin, z = x @ p["in_x"], x @ p["in_z"]
    window = jnp.concatenate([conv_st, xin], axis=1)          # [B, K, di]
    k = p["conv_w"].shape[0]
    xc = jnp.einsum("bkc,kc->bc", window.astype(F32),
                    p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
    xc = jax.nn.silu(xc)[:, None, :]                           # [B,1,di]
    proj = xc.astype(x.dtype) @ p["x_proj"]
    dtr = p["dt_proj"].shape[0]
    st_dim = (proj.shape[-1] - dtr) // 2
    dt_in, Bm, Cm = jnp.split(proj, [dtr, dtr + st_dim], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(F32)
                         + p["dt_bias"].astype(F32))[:, 0]     # [B, di]
    A = -jnp.exp(p["A_log"].astype(F32))
    dA = jnp.exp(dt[..., None] * A[None])                      # [B, di, st]
    h_new = dA * h + (dt * xc[:, 0])[..., None] * Bm.astype(F32)[:, 0, None, :]
    y = jnp.einsum("bds,bs->bd", h_new, Cm.astype(F32)[:, 0])
    y = y + p["D"][None] * xc[:, 0]
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], (window[:, 1:], h_new)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD chunked)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg, dtype) -> dict:
    d, di, st, k = cfg.d_model, cfg.di, cfg.ssm_state, cfg.ssm_conv
    nh = di // cfg.ssm_head_dim
    keys = jax.random.split(key, 5)
    s = d ** -0.5
    conv_dim = di + 2 * st
    return {
        "in_z": (jax.random.normal(keys[0], (d, di)) * s).astype(dtype),
        "in_xbc": (jax.random.normal(keys[3], (d, di + 2 * st)) * s).astype(dtype),
        "in_dt": (jax.random.normal(keys[4], (d, nh)) * s).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (k, conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.full((nh,), -2.0, F32),
        "A_log": jnp.zeros((nh,), F32),
        "D": jnp.ones((nh,), F32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(keys[2], (di, d)) * di ** -0.5).astype(dtype),
    }


def _ssd_chunked(xh, Bm, Cm, loga, chunk: int):
    """SSD: xh [B,L,nh,hd], Bm/Cm [B,L,st], loga [B,L,nh] (log decay ≤ 0).
    Returns (y [B,L,nh,hd], h_final [B,nh,hd,st])."""
    b, l, nh, hd = xh.shape
    st = Bm.shape[-1]
    t = min(chunk, l)
    assert l % t == 0
    nc = l // t
    xh_ = xh.reshape(b, nc, t, nh, hd)
    B_ = Bm.reshape(b, nc, t, st)
    C_ = Cm.reshape(b, nc, t, st)
    la = loga.reshape(b, nc, t, nh)
    lcum = jnp.cumsum(la, axis=2)                               # [b,nc,t,nh]
    # intra-chunk: scores[i,j] = exp(lcum_i - lcum_j) * (C_i . B_j), j <= i
    g = jnp.einsum("bcis,bcjs->bcij", C_, B_)                   # [b,nc,t,t]
    decay = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]     # [b,nc,i,j,nh]
    mask = (jnp.arange(t)[:, None] >= jnp.arange(t)[None, :])
    w = jnp.where(mask[None, None, :, :, None],
                  jnp.exp(decay), 0.0) * g[..., None]           # [b,nc,i,j,nh]
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", w, xh_)
    # chunk states: S_c = sum_j exp(lT - lcum_j) * B_j ⊗ x_j
    ldec = lcum[:, :, -1:, :] - lcum                            # [b,nc,t,nh]
    xw = xh_ * jnp.exp(ldec)[..., None]
    S = jnp.einsum("bcjs,bcjhd->bchds", B_, xw)                 # [b,nc,nh,hd,st]
    # inter-chunk scan: S_in[c] = decay_total[c-1] * S_in[c-1] + S[c-1]
    total = jnp.exp(lcum[:, :, -1, :])                          # [b,nc,nh]

    def step(carry, xs):
        tot, s_c = xs
        out = carry
        new = tot[..., None, None] * carry + s_c
        return new, out

    init = jnp.zeros((b, nh, hd, st), F32)
    S_final, S_in = jax.lax.scan(
        step, init, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(S, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                             # state entering chunk c
    y_inter = jnp.einsum("bcis,bchds->bcihd", C_, S_in) \
        * jnp.exp(lcum)[..., None]
    y = (y_intra + y_inter).reshape(b, l, nh, hd)
    return y, S_final


def _mamba2_fwd(p: dict, x: jnp.ndarray, chunk: int):
    b, l, _ = x.shape
    di = p["out_proj"].shape[0]
    nh = p["A_log"].shape[0]
    hd = di // nh
    st = (p["in_xbc"].shape[1] - di) // 2
    z = x @ p["in_z"]
    xbc = x @ p["in_xbc"]
    dt_in = x @ p["in_dt"]
    xbc = shard(xbc, "batch", "seq", "d_inner")
    xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, Bm, Cm = jnp.split(xbc_conv, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(F32) + p["dt_bias"][None, None])
    loga = -jnp.exp(p["A_log"])[None, None] * dt                # [B,L,nh] ≤ 0
    xh = (xin.astype(F32) * dt.repeat(hd, axis=-1)).reshape(b, l, nh, hd)
    y, h_final = _ssd_chunked(xh, Bm.astype(F32), Cm.astype(F32), loga, chunk)
    y = y + p["D"][None, None, :, None] * xin.astype(F32).reshape(b, l, nh, hd)
    y = y.reshape(b, l, di).astype(x.dtype)
    y = rms_norm_gated(y, z, p["norm_w"])
    k = p["conv_w"].shape[0]
    return y @ p["out_proj"], (xbc[:, -(k - 1):, :], h_final)


def mamba2(p: dict, x: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Full-sequence Mamba-2 block. x: [B, L, d]."""
    return _mamba2_fwd(p, x, chunk)[0]


def mamba2_prefill(p: dict, x: jnp.ndarray, chunk: int = 256):
    """Full-sequence forward that also returns the decode state."""
    return _mamba2_fwd(p, x, chunk)


def rms_norm_gated(y, z, w, eps: float = 1e-6):
    y32 = y.astype(F32) * jax.nn.silu(z.astype(F32))
    n = y32 * jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True) + eps)
    return (n * w.astype(F32)).astype(y.dtype)


def mamba2_decode(p: dict, x: jnp.ndarray, state):
    """One-token step; state = (conv [B,K-1,conv_dim], h [B,nh,hd,st])."""
    conv_st, h = state
    di = p["out_proj"].shape[0]
    nh = p["A_log"].shape[0]
    hd = di // nh
    st = (p["in_xbc"].shape[1] - di) // 2
    z = x @ p["in_z"]
    xbc = x @ p["in_xbc"]
    dt_in = x @ p["in_dt"]
    window = jnp.concatenate([conv_st, xbc], axis=1)
    xc = jnp.einsum("bkc,kc->bc", window.astype(F32),
                    p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
    xc = jax.nn.silu(xc)
    xin, Bm, Cm = jnp.split(xc, [di, di + st], axis=-1)        # [B, .]
    dt = jax.nn.softplus(dt_in.astype(F32)[:, 0] + p["dt_bias"][None])  # [B,nh]
    a = jnp.exp(-jnp.exp(p["A_log"])[None] * dt)               # [B,nh]
    xh = (xin * dt.repeat(hd, axis=-1)).reshape(-1, nh, hd)
    h_new = a[..., None, None] * h + xh[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhds,bs->bhd", h_new, Cm)
    y = y + p["D"][None, :, None] * xin.reshape(-1, nh, hd)
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = rms_norm_gated(y, z, p["norm_w"])
    return y @ p["out_proj"], (window[:, 1:], h_new)
