"""Model assembly for all assigned architectures.

One generic decoder stack parameterised by ``ArchConfig.layer_pattern``:
layers are stacked per *pattern group* and iterated with ``lax.scan`` (one
compiled group body regardless of depth — essential for 1-CPU-core compile
times and for clean layer-boundary remat).  Pattern kinds:

  global/local/chunked        — GQA attention (+ SwiGLU MLP or MoE)
  mamba1 / mamba2             — SSM blocks
  mamba2+shared_attn          — zamba2: Mamba-2 then the weight-SHARED
                                attention block on concat[h, x_embed]

Frontend stubs (docs/design.md §4): vision = precomputed patch embeddings
(projected + concatenated before the stack); audio = per-codebook embedding
sum with per-codebook output heads.

Decode state: per pattern-position stacked caches — rolling KV for
local/chunked layers (window-sized), full-length KV for global layers,
(conv, h) recurrent state for SSM layers.  Cache-entry absolute positions
are recovered arithmetically from the decode position, so no validity
bookkeeping is stored.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (attention, attn_qkv, decode_attention, init_attn,
                     init_mlp, mlp, rms_norm)

F32 = jnp.float32

# Dry-run cost accounting: when True, the layer-group scans fully unroll so
# XLA cost_analysis (which counts a scan body once) sees true totals.
UNROLL_SCANS = False


def is_attn_kind(kind: str) -> bool:
    return kind in ("global", "local", "chunked")


def base_kind(kind: str) -> str:
    return kind.split("+")[0]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, kind: str, key, dtype) -> dict:
    d = cfg.d_model
    if is_attn_kind(kind):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "ln1": jnp.ones((d,), dtype),
            "attn": init_attn(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, d,
                              cfg.qk_norm, dtype),
            "ln2": jnp.ones((d,), dtype),
        }
        if cfg.n_experts > 0:
            p["moe"] = moe_mod.init_moe(k2, d, cfg.d_ff, cfg.n_experts, dtype)
            if cfg.moe_dense_residual or cfg.shared_expert:
                p["dense"] = init_mlp(k3, d, cfg.d_ff, dtype)
        else:
            p["mlp"] = init_mlp(k2, d, cfg.d_ff, dtype)
        return p
    if base_kind(kind) == "mamba1":
        return {"ln": jnp.ones((d,), dtype),
                "m": ssm_mod.init_mamba1(key, cfg, dtype)}
    if base_kind(kind) == "mamba2":
        return {"ln": jnp.ones((d,), dtype),
                "m": ssm_mod.init_mamba2(key, cfg, dtype)}
    raise ValueError(kind)


def _init_shared_attn(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((2 * d,), dtype),
        "attn": init_attn(k1, 2 * d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, d,
                          cfg.qk_norm, dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": init_mlp(k2, d, cfg.d_ff, dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab
    params: Dict[str, Any] = {}
    if cfg.frontend == "audio_stub":
        params["embed"] = (jax.random.normal(keys[0], (cfg.n_codebooks, v, d))
                           * d ** -0.5).astype(dtype)
        params["lm_head"] = (jax.random.normal(keys[1], (cfg.n_codebooks, d, v))
                             * d ** -0.5).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(keys[0], (v, d)) * d ** -0.5).astype(dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(keys[1], (d, v))
                                 * d ** -0.5).astype(dtype)
    if cfg.frontend == "vision_stub":
        params["vision_proj"] = (jax.random.normal(keys[2], (d, d))
                                 * d ** -0.5).astype(dtype)
    g = cfg.n_groups
    groups = []
    for j, kind in enumerate(cfg.layer_pattern):
        lkeys = jax.random.split(jax.random.fold_in(keys[3], j), g)
        groups.append(jax.vmap(
            lambda kk, kind=kind: _init_layer(cfg, kind, kk, dtype))(lkeys))
    params["groups"] = tuple(groups)
    if any("shared_attn" in k for k in cfg.layer_pattern):
        params["shared_attn"] = _init_shared_attn(cfg, keys[4], dtype)
    params["final_norm"] = jnp.ones((d,), dtype)
    return params


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ArchConfig, params, batch) -> jnp.ndarray:
    if cfg.frontend == "audio_stub":
        codes = batch["tokens"]                     # [B, L, nc]
        h = sum(jnp.take(params["embed"][c], codes[:, :, c], axis=0)
                for c in range(cfg.n_codebooks))    # Σ_c embed_c[codes_c]
    elif cfg.frontend == "vision_stub" and "patch_emb" in batch:
        # prefill/train: precomputed patch embeddings prefix (decode is
        # text-only and takes the plain-token path below)
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)  # [B, Lt, d]
        patch = batch["patch_emb"].astype(tok.dtype) @ params["vision_proj"]
        h = jnp.concatenate([patch, tok], axis=1)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    return shard(h, "batch", "seq", "d_model")


def unembed(cfg: ArchConfig, params, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.frontend == "audio_stub":
        logits = jnp.einsum("bld,cdv->blcv", h, params["lm_head"])
        return shard(logits, "batch", "seq", "codebooks", "vocab")
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return shard(h @ head, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# layer application (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _apply_ffn(cfg: ArchConfig, lp, h):
    f_in = rms_norm(h, lp["ln2"])
    aux = jnp.float32(0.0)
    if cfg.n_experts > 0:
        y, aux = moe_mod.moe_layer(lp["moe"], f_in, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor)
        if "dense" in lp:
            y = y + mlp(lp["dense"], f_in)
    else:
        y = mlp(lp["mlp"], f_in)
    return h + y, aux


def _apply_attn_layer(cfg: ArchConfig, lp, h, pos, kind: str):
    a_in = rms_norm(h, lp["ln1"])
    q, k, v = attn_qkv(lp["attn"], a_in, pos, n_heads=cfg.n_heads,
                       n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta,
                       qk_norm=cfg.qk_norm)
    o = attention(q, k, v, kind=kind, window=cfg.window)
    b, l = h.shape[:2]
    h = h + o.reshape(b, l, -1) @ lp["attn"]["wo"]
    h, aux = _apply_ffn(cfg, lp, h)
    return h, (k, v), aux


def _apply_shared_attn(cfg: ArchConfig, sp, h, x0, pos):
    inp = jnp.concatenate([h, x0], axis=-1)
    a_in = rms_norm(inp, sp["ln1"])
    q, k, v = attn_qkv(sp["attn"], a_in, pos, n_heads=cfg.n_heads,
                       n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta,
                       qk_norm=cfg.qk_norm)
    o = attention(q, k, v, kind="global", window=cfg.window)
    b, l = h.shape[:2]
    h = h + o.reshape(b, l, -1) @ sp["attn"]["wo"]
    m_in = rms_norm(h, sp["ln2"])
    h = h + mlp(sp["mlp"], m_in)
    return h, (k, v)


def forward(cfg: ArchConfig, params, batch, *, collect_state: bool = False,
            cache_len: Optional[int] = None):
    """Full-sequence forward.

    Returns (logits, aux_loss) or, with collect_state, (logits, aux, state)
    where state matches ``init_decode_state`` layout.
    """
    h = embed_inputs(cfg, params, batch)
    x0 = h
    l = h.shape[1]
    pos = jnp.arange(l)
    s_cache = cache_len if cache_len is not None else l
    shared = params.get("shared_attn")

    def group_body(carry, gp):
        h, aux = carry
        states = []
        for j, kind in enumerate(cfg.layer_pattern):
            lp = gp[j]
            bk = base_kind(kind)
            if is_attn_kind(bk):
                h, kv, a = _apply_attn_layer(cfg, lp, h, pos, bk)
                aux = aux + a
                if collect_state:
                    states.append(_fill_kv_cache(kv, _cache_len(cfg, bk, s_cache), l))
            elif bk == "mamba1":
                m_in = rms_norm(h, lp["ln"])
                if collect_state:
                    y, st = ssm_mod.mamba1_prefill(lp["m"], m_in)
                    states.append(st)
                else:
                    y = ssm_mod.mamba1(lp["m"], m_in)
                h = h + y
            elif bk == "mamba2":
                m_in = rms_norm(h, lp["ln"])
                if collect_state:
                    y, st = ssm_mod.mamba2_prefill(lp["m"], m_in)
                    states.append(st)
                else:
                    y = ssm_mod.mamba2(lp["m"], m_in)
                h = h + y
            if "shared_attn" in kind:
                h, kv = _apply_shared_attn(cfg, shared, h, x0, pos)
                if collect_state:
                    states.append(_fill_kv_cache(kv, _cache_len(cfg, "global", s_cache), l))
            h = shard(h, "batch", "seq", "d_model")
        return (h, aux), tuple(states) if collect_state else None

    body = group_body if collect_state else jax.checkpoint(
        group_body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), states = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                    params["groups"],
                                    unroll=True if UNROLL_SCANS else 1)
    h = rms_norm(h, params["final_norm"])
    logits = unembed(cfg, params, h)
    if collect_state:
        return logits, aux, states
    return logits, aux


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

def _cache_len(cfg: ArchConfig, kind: str, s: int) -> int:
    if kind == "global":
        return s
    return min(s, cfg.window)


def _fill_kv_cache(kv, s_c: int, l: int):
    """Pack prefill k/v [B, L, KVH, hd] into a rolling cache of length s_c."""
    k, v = kv

    def pack(a):
        if s_c >= l:
            pad = [(0, 0)] * a.ndim
            pad[1] = (0, s_c - l)
            return jnp.pad(a, pad)
        tail = a[:, l - s_c:]
        return jnp.roll(tail, l % s_c, axis=1)

    return pack(k), pack(v)


def init_decode_state(cfg: ArchConfig, batch: int, s: int, dtype=jnp.bfloat16):
    """Empty caches (decode-from-scratch) in the same layout forward(...,
    collect_state=True) produces: tuple over groups? No — stacked [G, ...]
    per pattern position, matching lax.scan's ys stacking."""
    g = cfg.n_groups
    kvh, hd = cfg.n_kv_heads, cfg.hd
    states = []
    for kind in cfg.layer_pattern:
        bk = base_kind(kind)
        if is_attn_kind(bk):
            s_c = _cache_len(cfg, bk, s)
            shp = (g, batch, s_c, kvh, hd)
            states.append((jnp.zeros(shp, dtype), jnp.zeros(shp, dtype)))
        elif bk == "mamba1":
            di, st, k = cfg.di, cfg.ssm_state, cfg.ssm_conv
            states.append((jnp.zeros((g, batch, k - 1, di), dtype),
                           jnp.zeros((g, batch, di, st), F32)))
        elif bk == "mamba2":
            di, st, k = cfg.di, cfg.ssm_state, cfg.ssm_conv
            nh = di // cfg.ssm_head_dim
            conv_dim = di + 2 * st
            states.append((jnp.zeros((g, batch, k - 1, conv_dim), dtype),
                           jnp.zeros((g, batch, nh, cfg.ssm_head_dim, st), F32)))
        if "shared_attn" in kind:
            s_c = _cache_len(cfg, "global", s)
            shp = (g, batch, s_c, kvh, hd)
            states.append((jnp.zeros(shp, dtype), jnp.zeros(shp, dtype)))
    return tuple(states)


def _entry_positions(s_c: int, pos) -> jnp.ndarray:
    """Absolute position of each rolling-cache slot after writing at `pos`;
    negative values mark not-yet-written slots."""
    slot = pos % s_c
    i = jnp.arange(s_c)
    return pos - ((slot - i) % s_c)


def _decode_attn(cfg, ap, h_in, kv_cache, pos, kind, wo):
    """Shared decode attention: h_in [B, 1, d_in]; returns (attn_out, cache).

    The cache's sequence axis is model-sharded ("kv_seq" rule); the write
    is a masked broadcast (shard-local — a dynamic-update-slice on a
    sharded axis would force a gather), and q stays replicated across
    "model" so the only cross-device traffic is the softmax/output
    reduction over the sharded S axis (O(B·H) scalars)."""
    k_c, v_c = kv_cache
    s_c = k_c.shape[1]
    q, k, v = attn_qkv(ap, h_in, pos[None], n_heads=cfg.n_heads,
                       n_kv=cfg.n_kv_heads, hd=cfg.hd, theta=cfg.rope_theta,
                       qk_norm=cfg.qk_norm)
    q = shard(q, "batch", None, None, None)
    slot = pos % s_c
    slot_mask = (jnp.arange(s_c) == slot)[None, :, None, None]
    k_c = jnp.where(slot_mask, k.astype(k_c.dtype), k_c)
    v_c = jnp.where(slot_mask, v.astype(v_c.dtype), v_c)
    k_c = shard(k_c, "batch", "kv_seq", "kv_heads", "head_dim")
    v_c = shard(v_c, "batch", "kv_seq", "kv_heads", "head_dim")
    epos = _entry_positions(s_c, pos)[None, :]
    o = decode_attention(q, k_c, v_c, epos, pos, kind=kind, window=cfg.window)
    b = h_in.shape[0]
    return o.reshape(b, 1, -1) @ wo, (k_c, v_c)


def decode_step(cfg: ArchConfig, params, state, batch, pos):
    """One decode step.  batch["tokens"]: [B, 1] (audio: [B, 1, nc]);
    pos: scalar absolute position.  Returns (logits, new_state)."""
    h = embed_inputs(cfg, params, batch)
    x0 = h
    shared = params.get("shared_attn")

    def group_body(carry, xs):
        h = carry
        gp, caches = xs
        new_states = []
        ci = 0
        for j, kind in enumerate(cfg.layer_pattern):
            lp = gp[j]
            bk = base_kind(kind)
            if is_attn_kind(bk):
                a_in = rms_norm(h, lp["ln1"])
                o, kv = _decode_attn(cfg, lp["attn"], a_in, caches[ci], pos,
                                     bk, lp["attn"]["wo"])
                h = h + o
                h, _ = _apply_ffn(cfg, lp, h)
                new_states.append(kv)
                ci += 1
            elif bk == "mamba1":
                m_in = rms_norm(h, lp["ln"])
                y, st = ssm_mod.mamba1_decode(lp["m"], m_in, caches[ci])
                h = h + y
                new_states.append(st)
                ci += 1
            elif bk == "mamba2":
                m_in = rms_norm(h, lp["ln"])
                y, st = ssm_mod.mamba2_decode(lp["m"], m_in, caches[ci])
                h = h + y
                new_states.append(st)
                ci += 1
            if "shared_attn" in kind:
                inp = jnp.concatenate([h, x0], axis=-1)
                a_in = rms_norm(inp, shared["ln1"])
                o, kv = _decode_attn(cfg, shared["attn"], a_in, caches[ci],
                                     pos, "global", shared["attn"]["wo"])
                h = h + o
                h = h + mlp(shared["mlp"], rms_norm(h, shared["ln2"]))
                new_states.append(kv)
                ci += 1
        return h, tuple(new_states)

    h, new_state = jax.lax.scan(group_body, h, (params["groups"], state),
                                unroll=True if UNROLL_SCANS else 1)
    h = rms_norm(h, params["final_norm"])
    logits = unembed(cfg, params, h)
    return logits, new_state
