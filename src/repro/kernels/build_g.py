"""Pallas TPU kernel: fused BUILD-step arm statistics.

This is the paper's hot loop (≥98 % of wall clock is distance evaluation).
One program computes, for a [TM]-tile of candidate arms against the whole
reference batch (B ≤ 512 resident in VMEM):

    d(x, y_j)                                  — MXU (or VPU for L1)
    g = (d − d_near_j) ∧ 0                     — Eq. 6 clamp, in VMEM
    Σ_j g,  Σ_j g²,  Σ_j g·g_lead              — streaming arm statistics

and writes only the three [TM] stat vectors back to HBM.  The [TM, B]
distance tile never leaves VMEM — on a v5e this turns an HBM-bound
O(n·B) tensor round-trip into three O(n) vectors (arithmetic intensity
rises from ~1 flop/byte to ~B flops/byte on the output side).

VMEM at TM=128, B=512, D=1024: x 512 KiB + y 2 MiB + tile 256 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import dist_tile


def _kernel(x_ref, y_ref, dn_ref, w_ref, lg_ref, sums_ref, sq_ref, cross_ref,
            *, metric):
    d = dist_tile(x_ref[...], y_ref[...], metric)        # [TM, B]
    dn = dn_ref[0, :][None, :]                            # [1, B]
    w = w_ref[0, :][None, :]
    g = jnp.where(jnp.isinf(dn), d, jnp.minimum(d - dn, 0.0)) * w
    sums_ref[0, :] = jnp.sum(g, axis=1)
    sq_ref[0, :] = jnp.sum(g * g, axis=1)
    cross_ref[0, :] = g @ lg_ref[0, :]


@functools.partial(jax.jit, static_argnames=("metric", "tm", "interpret"))
def build_g_kernel(x, y, dnear_b, w, lead_g, *, metric: str, tm: int = 128,
                   interpret: bool = False):
    """Pre-padded entry point.

    x: [m, d] candidate arms; y: [B, d] reference batch; dnear_b, w,
    lead_g: [B].  Returns (sums[m], sqsums[m], cross[m]).
    """
    m, d = x.shape
    b = y.shape[0]
    assert m % tm == 0 and d % 128 == 0 and b % 128 == 0, (m, d, b)
    grid = (m // tm,)
    vec = lambda: pl.BlockSpec((1, b), lambda i: (0, 0))
    out = lambda: pl.BlockSpec((1, tm), lambda i: (0, i))
    sums, sq, cross = pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            vec(), vec(), vec(),
        ],
        out_specs=[out(), out(), out()],
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32)] * 3,
        interpret=interpret,
    )(x, y, dnear_b[None, :], w[None, :], lead_g[None, :])
    return sums[0], sq[0], cross[0]
