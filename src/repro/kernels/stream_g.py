"""Pallas TPU kernels: streaming g-stats megakernel family.

The one-shot kernels (``build_g``, ``swap_g``) hold the WHOLE reference
batch resident in VMEM, which caps B at a round-batch.  These kernels
lift that cap the way memory-efficient attention does for KV length: the
grid's minor axis **walks reference tiles** (``tb`` columns each) while
the output block for the current candidate tile stays VMEM-resident, so
per-arm statistics and top-2 reductions accumulate **online** and the
``[m, r]`` distance matrix never exists in HBM at any r — one dispatch
covers the full reference set (r = n for the exact fallback passes).

Pipelining: ``pallas_call`` double-buffers every operand whose BlockSpec
index changes along the grid — here the [tb, d] reference tile and its
per-reference vectors — so the next tile's DMA overlaps the current
tile's MXU/VPU work; no hand-rolled ``make_async_copy`` needed.  The
output BlockSpecs are invariant along the minor axis, which keeps the
accumulator block in VMEM across the whole reference walk (one HBM
write-back per candidate tile).

Accumulation-order contract (bit-parity with the jnp engine paths): a
tile's stats are reduced with the exact op order of the one-shot kernels
(row-sum / one-hot ``dot_general`` over the tb axis), then tiles are
added in walk order.  With ``tb`` pinned to the engine's historical
``_EXACT_CHUNK`` (see ``repro.core.tuning.REF_TILE``) this reproduces
the chunked ``lax.scan`` ledgers bit-for-bit; see docs/design.md #8.

VMEM at tm=tb=512, d=1024, f32: x-tile 2 MiB + y-tile 2 MiB (x2 for the
pipeline) + stat blocks < 1 MiB — the tuner (``repro.core.tuning``)
sizes tm/dk against this budget per (n, d, k, device kind).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import dist_tile
from .swap_g import swap_stats_vals


def _build_kernel(x_ref, y_ref, dn_ref, w_ref, lg_ref,
                  sums_ref, sq_ref, cross_ref, *, metric):
    j = pl.program_id(1)
    d = dist_tile(x_ref[...], y_ref[...], metric)         # [TM, TB]
    dn = dn_ref[0, :][None, :]
    w = w_ref[0, :][None, :]
    g = jnp.where(jnp.isinf(dn), d, jnp.minimum(d - dn, 0.0)) * w

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)

    sums_ref[0, :] += jnp.sum(g, axis=1)
    sq_ref[0, :] += jnp.sum(g * g, axis=1)
    cross_ref[0, :] += g @ lg_ref[0, :]


@functools.partial(jax.jit,
                   static_argnames=("metric", "tm", "tb", "interpret"))
def stream_build_g_kernel(x, y, dnear, w, lead_g, *, metric: str,
                          tm: int = 128, tb: int = 512,
                          interpret: bool = False):
    """Pre-padded streaming BUILD stats over the full reference set.

    x: [m, d] candidate arms; y: [r, d] references (r unbounded — the
    grid walks it in ``tb``-tiles); dnear, w, lead_g: [r].  Returns
    (sums[m], sqsums[m], cross[m]) — Σ over ALL r references.
    """
    m, d = x.shape
    r = y.shape[0]
    assert m % tm == 0 and r % tb == 0 and d % 128 == 0, (m, r, d)
    grid = (m // tm, r // tb)
    vec = lambda: pl.BlockSpec((1, tb), lambda i, j: (0, j))
    out = lambda: pl.BlockSpec((1, tm), lambda i, j: (0, i))
    sums, sq, cross = pl.pallas_call(
        functools.partial(_build_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, d), lambda i, j: (j, 0)),
            vec(), vec(), vec(),
        ],
        out_specs=[out(), out(), out()],
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32)] * 3,
        interpret=interpret,
    )(x, y, dnear[None, :], w[None, :], lead_g[None, :])
    return sums[0], sq[0], cross[0]


def _swap_kernel(x_ref, y_ref, d1_ref, d2_ref, oh_ref, lg_ref,
                 sums_ref, sq_ref, cross_ref, *, metric):
    j = pl.program_id(1)
    d = dist_tile(x_ref[...], y_ref[...], metric)         # [TM, TB]
    sums, sq, cross = swap_stats_vals(d, d1_ref[0, :], d2_ref[0, :],
                                      oh_ref[...], lg_ref[0, :])

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)
        cross_ref[...] = jnp.zeros_like(cross_ref)

    sums_ref[...] += sums
    sq_ref[...] += sq
    cross_ref[...] += cross


@functools.partial(jax.jit,
                   static_argnames=("metric", "tm", "tb", "interpret"))
def stream_swap_g_kernel(x, y, d1, d2, onehot_w, lead_g, *, metric: str,
                         tm: int = 128, tb: int = 512,
                         interpret: bool = False):
    """Pre-padded streaming SWAP (FastPAM1) stats over the full reference
    set: same per-tile math as ``swap_g_kernel`` (via
    ``swap_stats_vals``), accumulated along the reference walk.

    x: [m, d]; y: [r, d]; d1, d2, lead_g: [r]; onehot_w: [r, K]
    (w-folded; lead_g w-masked).  Returns (sums, sqsums, cross), [m, K].
    """
    m, d = x.shape
    r, kp = onehot_w.shape
    assert m % tm == 0 and r % tb == 0 and d % 128 == 0 and kp % 128 == 0
    grid = (m // tm, r // tb)
    vec = lambda: pl.BlockSpec((1, tb), lambda i, j: (0, j))
    out = lambda: pl.BlockSpec((tm, kp), lambda i, j: (i, 0))
    sums, sq, cross = pl.pallas_call(
        functools.partial(_swap_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, d), lambda i, j: (j, 0)),
            vec(), vec(),
            pl.BlockSpec((tb, kp), lambda i, j: (j, 0)),
            vec(),
        ],
        out_specs=[out(), out(), out()],
        out_shape=[jax.ShapeDtypeStruct((m, kp), jnp.float32)] * 3,
        interpret=interpret,
    )(x, y, d1[None, :], d2[None, :], onehot_w, lead_g[None, :])
    return sums, sq, cross


def _top2_kernel(x_ref, med_ref, mask_ref, d1_ref, d2_ref, a_ref, *,
                 metric):
    d = dist_tile(x_ref[...], med_ref[...], metric)       # [TM, KP]
    kp = d.shape[1]
    d = jnp.where(mask_ref[0, :][None, :] > 0.0, d, jnp.inf)
    d1 = jnp.min(d, axis=1)
    # First index attaining the min, via a min-reduce over masked column
    # ids (Mosaic-safe; matches jnp.argmin's first-occurrence tie rule).
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    a = jnp.min(jnp.where(d == d1[:, None], col, kp), axis=1)
    d2 = jnp.min(jnp.where(col == a[:, None], jnp.inf, d), axis=1)
    d1_ref[0, :] = d1
    d2_ref[0, :] = d2
    a_ref[0, :] = a


@functools.partial(jax.jit, static_argnames=("metric", "tm", "interpret"))
def stream_top2_kernel(x, med, kmask, *, metric: str, tm: int = 128,
                       interpret: bool = False):
    """Pre-padded streaming nearest/second-nearest reduction.

    x: [n, d] points (the grid walks candidate tiles); med: [KP, d]
    medoid rows (resident — k is small); kmask: [KP] {0,1} marking real
    medoid columns.  Returns (d1[n], d2[n], assign[n] int32); the
    [n, k] distance matrix never exists in HBM.
    """
    n, d = x.shape
    kp = med.shape[0]
    assert n % tm == 0 and d % 128 == 0 and kp % 128 == 0, (n, d, kp)
    grid = (n // tm,)
    out = lambda dt: pl.BlockSpec((1, tm), lambda i: (0, i))
    d1, d2, a = pl.pallas_call(
        functools.partial(_top2_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((kp, d), lambda i: (0, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_specs=[out(jnp.float32), out(jnp.float32), out(jnp.int32)],
        out_shape=[jax.ShapeDtypeStruct((1, n), jnp.float32),
                   jax.ShapeDtypeStruct((1, n), jnp.float32),
                   jax.ShapeDtypeStruct((1, n), jnp.int32)],
        interpret=interpret,
    )(x, med, kmask[None, :])
    return d1[0], d2[0], a[0]
