"""Pallas TPU kernel: fused SWAP-step (FastPAM1) arm statistics.

One program computes, for a [TM]-tile of candidate points x against the
resident reference batch, the statistics of ALL k medoid-arms (m, x) at
once — the FastPAM1 sharing (Appendix 1.1) executed inside VMEM:

    d(x, y_j)                                   — MXU / VPU
    base = min(d, d₁) − d₁                      — Eq. 12 common term
    corr = min(d, d₂) − min(d, d₁)              — Eq. 12 cluster term
    Σg   [TM, K] = Σ base  ⊕  corr  @ onehot    — MXU one-hot matmul
    Σg²  [TM, K] = Σ base² ⊕ (2·base·corr + corr²) @ onehot
    Σg·g_lead [TM, K]                            — leader control variate

The [TM, B] base/corr tiles never touch HBM; only three [TM, K] stat
blocks are written.  ``onehot`` is the padding-weighted cluster-assignment
one-hot [B, K] (K padded to a lane multiple), so the reduction over C_m is
a [TM, B] x [B, K] systolic matmul.

``swap_g_from_cache_kernel`` is the BanditPAM++ PIC variant: the distance
tile is read from a resident cached column block (warm rounds and
carried-statistic repairs) instead of being recomputed — the d/base/corr
pipeline after the distance pass is byte-identical.  Its ``B`` is the
caller's block width: one bandit round-batch for warm rounds, or up to
the capped PIC ring width for the carried-statistic repair
(``ops.swap_g_stats_cached`` splits widths past its VMEM budget into
additive chunks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import dist_tile


def swap_stats_vals(d, d1, d2, oh, lg):
    """Pure fused-stats tile math: [TM, B] distances + per-reference
    vectors -> the three [TM, K] stat blocks.  Shared by the one-shot
    kernels here and the streaming megakernel (``stream_g``), so every
    SWAP surface reduces one tile with byte-identical op order."""
    d1 = d1[None, :]
    d2 = d2[None, :]
    w = jnp.sign(jnp.sum(oh, axis=1))[None, :]            # recover {0,1} mask
    base = (jnp.minimum(d, d1) - d1) * w
    corr = jnp.minimum(d, d2) - jnp.minimum(d, d1)
    dot = lambda a: jax.lax.dot_general(
        a, oh, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    sums = jnp.sum(base, 1, keepdims=True) + dot(corr)
    sq = jnp.sum(base * base, 1, keepdims=True) + dot(
        2.0 * base * corr + corr * corr)
    cross = (base @ lg)[:, None] + dot(corr * lg[None, :])
    return sums, sq, cross


def _stats_from_d(d, d1_ref, d2_ref, oh_ref, lg_ref,
                  sums_ref, sq_ref, cross_ref):
    """Shared fused-stats body, given the [TM, B] distance tile ``d``."""
    sums, sq, cross = swap_stats_vals(d, d1_ref[0, :], d2_ref[0, :],
                                      oh_ref[...], lg_ref[0, :])
    sums_ref[...] = sums
    sq_ref[...] = sq
    cross_ref[...] = cross


def _kernel(x_ref, y_ref, d1_ref, d2_ref, oh_ref, lg_ref,
            sums_ref, sq_ref, cross_ref, *, metric):
    d = dist_tile(x_ref[...], y_ref[...], metric)        # [TM, B]
    _stats_from_d(d, d1_ref, d2_ref, oh_ref, lg_ref,
                  sums_ref, sq_ref, cross_ref)


def _kernel_cached(d_ref, d1_ref, d2_ref, oh_ref, lg_ref,
                   sums_ref, sq_ref, cross_ref):
    # BanditPAM++ PIC warm path: the distance tile comes straight from the
    # resident cache block — no MXU distance pass, stats only.
    _stats_from_d(d_ref[...], d1_ref, d2_ref, oh_ref, lg_ref,
                  sums_ref, sq_ref, cross_ref)


@functools.partial(jax.jit, static_argnames=("metric", "tm", "interpret"))
def swap_g_kernel(x, y, d1_b, d2_b, onehot_w, lead_g, *, metric: str,
                  tm: int = 128, interpret: bool = False):
    """Pre-padded entry point.

    x: [m, d]; y: [B, d]; d1_b, d2_b, lead_g: [B]; onehot_w: [B, K]
    (cluster one-hot with the {0,1} padding weights folded in; lead_g must
    also be w-masked).  Returns (sums, sqsums, cross) each [m, K] — arm
    (med j, cand i) lives at [i, j]; the ops wrapper transposes/crops.
    """
    m, d = x.shape
    b, kp = onehot_w.shape
    assert m % tm == 0 and d % 128 == 0 and b % 128 == 0 and kp % 128 == 0
    grid = (m // tm,)
    vec = lambda: pl.BlockSpec((1, b), lambda i: (0, 0))
    out = lambda: pl.BlockSpec((tm, kp), lambda i: (i, 0))
    sums, sq, cross = pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            vec(), vec(),
            pl.BlockSpec((b, kp), lambda i: (0, 0)),
            vec(),
        ],
        out_specs=[out(), out(), out()],
        out_shape=[jax.ShapeDtypeStruct((m, kp), jnp.float32)] * 3,
        interpret=interpret,
    )(x, y, d1_b[None, :], d2_b[None, :], onehot_w, lead_g[None, :])
    return sums, sq, cross


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def swap_g_from_cache_kernel(dxy, d1_b, d2_b, onehot_w, lead_g, *,
                             tm: int = 128, interpret: bool = False):
    """PIC warm-round / carry-repair entry point: identical statistics to
    ``swap_g_kernel`` but fed from a resident cached distance block.

    dxy: [m, B] precomputed distances (a slice of the PIC column cache);
    d1_b, d2_b, lead_g: [B]; onehot_w: [B, K] (w-folded, lead_g w-masked).
    Returns (sums, sqsums, cross) each [m, K].
    """
    m, b = dxy.shape
    kp = onehot_w.shape[1]
    assert m % tm == 0 and b % 128 == 0 and kp % 128 == 0
    grid = (m // tm,)
    vec = lambda: pl.BlockSpec((1, b), lambda i: (0, 0))
    out = lambda: pl.BlockSpec((tm, kp), lambda i: (i, 0))
    sums, sq, cross = pl.pallas_call(
        _kernel_cached,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, b), lambda i: (i, 0)),
            vec(), vec(),
            pl.BlockSpec((b, kp), lambda i: (0, 0)),
            vec(),
        ],
        out_specs=[out(), out(), out()],
        out_shape=[jax.ShapeDtypeStruct((m, kp), jnp.float32)] * 3,
        interpret=interpret,
    )(dxy, d1_b[None, :], d2_b[None, :], onehot_w, lead_g[None, :])
    return sums, sq, cross
