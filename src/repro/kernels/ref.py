"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes with interpret=True).
They intentionally share NO code with the kernels themselves; they mirror
the math of ``repro.core`` directly.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_ref(x: jnp.ndarray, y: jnp.ndarray, metric: str) -> jnp.ndarray:
    """[m, d] x [r, d] -> [m, r] dissimilarity."""
    if metric == "l2sq":
        return jnp.maximum(
            jnp.sum(x * x, -1)[:, None] + jnp.sum(y * y, -1)[None, :]
            - 2.0 * x @ y.T, 0.0)
    if metric == "l2":
        return jnp.sqrt(pairwise_ref(x, y, "l2sq"))
    if metric == "cosine":
        xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-15)
        yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-15)
        return 1.0 - xn @ yn.T
    if metric == "l1":
        return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    raise ValueError(metric)


def build_g_ref(x, y, dnear_b, w, metric: str):
    """Fused BUILD statistics oracle.

    Returns (sums[m], sqsums[m]): weighted per-arm sums of
    g_x(y_j) = (d(x, y_j) - dnear_j) ∧ 0   (or d itself where dnear = +inf).
    """
    dxy = pairwise_ref(x, y, metric)
    dn = dnear_b[None, :]
    g = jnp.where(jnp.isinf(dn), dxy, jnp.minimum(dxy - dn, 0.0)) * w[None, :]
    return jnp.sum(g, -1), jnp.sum(g * g, -1)


def swap_g_ref(x, y, d1_b, d2_b, assign_b, w, k: int, metric: str):
    """Fused SWAP (FastPAM1, Eq. 12) statistics oracle.

    Returns (sums[k, m], sqsums[k, m]) for arms (medoid m_i, candidate x_j),
    computed via the dense [k, m, B] tensor (oracle only — the kernel never
    materialises it).
    """
    dxy = pairwise_ref(x, y, metric)                    # [m, B]
    in_cm = assign_b[None, :] == jnp.arange(k)[:, None]   # [k, B]
    g = jnp.where(in_cm[:, None, :],
                  -d1_b[None, None, :] + jnp.minimum(d2_b[None, None, :], dxy[None]),
                  -d1_b[None, None, :] + jnp.minimum(d1_b[None, None, :], dxy[None]))
    g = g * w[None, None, :]
    return jnp.sum(g, -1), jnp.sum(g * g, -1)
