"""Jit'd public wrappers around the Pallas kernels: padding, cropping,
interpret-mode selection, and TPU deployment hooks.

On this container (CPU) the kernels execute with ``interpret=True`` — the
kernel bodies run in Python for correctness validation; on a real TPU
backend the same code lowers to Mosaic.  ``install()`` re-registers the
``repro.core.distances`` metrics to the kernel-backed implementations for
TPU deployment.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import build_g as _build_g
from . import pairwise as _pairwise
from . import stream_g as _stream_g
from . import swap_g as _swap_g


# Metrics implemented by the Pallas kernels (the registry-facing names;
# the repro.api predict path and the repro.core.engine stats-backend
# resolution both key off this tuple).
KERNEL_METRICS = ("l2", "l2sq", "l1", "cosine")

# Feature-axis tile budget: one [128, DK_MAX] f32 operand tile is 4 MiB of
# VMEM.  Larger feature dims are split into dk-chunks whose additive cores
# (squared distances / abs-sums / dot products) accumulate exactly.
DK_MAX = 8192


def _default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def pairwise_distance(x: jnp.ndarray, y: jnp.ndarray, metric: str = "l2",
                      *, tm: int = 128, tr: int = 128, dk: int = DK_MAX,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """[m, d] x [r, d] -> [m, r] via the tiled Pallas kernel.

    Feature dims up to ``dk`` are VMEM-resident in one kernel pass.  Past
    that budget the feature axis is split into ``dk``-column chunks and the
    *additive* per-chunk core is accumulated across kernel calls — exact
    for every metric here: squared distances and abs-sums are sums over
    feature chunks, ``l2`` is the root of the accumulated ``l2sq``, and
    ``cosine`` accumulates the raw MXU dot product (internal ``"dot"``
    tile) with the O((m+r)·d) row norms computed outside the kernel.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, r, d = x.shape[0], y.shape[0], x.shape[1]
    if dk % 128 != 0:
        raise ValueError(f"dk must be a lane multiple of 128, got {dk}")
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    yp = _pad_to(_pad_to(y, 1, 128), 0, tr)
    if d <= dk:
        out = _pairwise.pairwise_kernel(xp, yp, metric=metric, tm=tm, tr=tr,
                                        interpret=interpret)
        return out[:m, :r]

    core = {"l2": "l2sq", "l2sq": "l2sq", "l1": "l1",
            "cosine": "dot"}.get(metric)
    if core is None:
        raise ValueError(f"unknown metric {metric!r}")
    if xp.shape[1] <= dk:
        acc = _pairwise.pairwise_kernel(xp, yp, metric=core, tm=tm, tr=tr,
                                        interpret=interpret)
    else:
        # Wide features accumulate through a lax loop with an additive
        # carry (one kernel trace regardless of d), instead of the
        # historical Python loop that unrolled one kernel call per
        # dk-chunk into the jit.  The lane padding moves to the last
        # chunk's tail, where the zero features leave every partial sum
        # untouched.
        xp = _pad_to(xp, 1, dk)
        yp = _pad_to(yp, 1, dk)
        n_ch = xp.shape[1] // dk

        def body(c, acc):
            xs = jax.lax.dynamic_slice_in_dim(xp, c * dk, dk, 1)
            ys = jax.lax.dynamic_slice_in_dim(yp, c * dk, dk, 1)
            return acc + _pairwise.pairwise_kernel(
                xs, ys, metric=core, tm=tm, tr=tr, interpret=interpret)

        acc = jax.lax.fori_loop(
            0, n_ch, body,
            jnp.zeros((xp.shape[0], yp.shape[0]), jnp.float32))
    acc = acc[:m, :r]
    if metric == "l2":
        return jnp.sqrt(acc)
    if metric == "cosine":
        xf = x.astype(jnp.float32)
        yf = y.astype(jnp.float32)
        xn = jax.lax.rsqrt(jnp.maximum(jnp.sum(xf * xf, -1), 1e-30))
        yn = jax.lax.rsqrt(jnp.maximum(jnp.sum(yf * yf, -1), 1e-30))
        return 1.0 - acc * xn[:, None] * yn[None, :]
    return acc


def build_g_stats(x: jnp.ndarray, y: jnp.ndarray, dnear_b: jnp.ndarray,
                  w: jnp.ndarray, lead_g: Optional[jnp.ndarray] = None,
                  *, metric: str = "l2", tm: int = 128,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused BUILD statistics: (Σg, Σg², Σg·g_lead) per arm, [m] each."""
    if interpret is None:
        interpret = _default_interpret()
    m = x.shape[0]
    if lead_g is None:
        lead_g = jnp.zeros_like(dnear_b)
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    yp = _pad_to(_pad_to(y, 1, 128), 0, 128)
    pad_b = yp.shape[0] - y.shape[0]
    dn = jnp.pad(dnear_b, (0, pad_b))
    wp = jnp.pad(w, (0, pad_b))               # padded refs get weight 0
    lg = jnp.pad(lead_g, (0, pad_b))
    sums, sq, cross = _build_g.build_g_kernel(xp, yp, dn, wp, lg,
                                              metric=metric, tm=tm,
                                              interpret=interpret)
    return sums[:m], sq[:m], cross[:m]


def _swap_prep(d1_b, d2_b, assign_b, w, k, lead_g, pad_b, row_mult=128):
    """Shared SWAP-kernel operand prep: pad the per-reference vectors,
    w-mask the leader row, w-fold + lane-pad the cluster one-hot.
    ``row_mult`` is the reference-axis tile the one-hot must align to
    (128 for the batch-resident kernels, ``tb`` for the streaming walk)."""
    if lead_g is None:
        lead_g = jnp.zeros_like(d1_b)
    d1 = jnp.pad(d1_b, (0, pad_b))
    d2 = jnp.pad(d2_b, (0, pad_b))
    lg = jnp.pad(lead_g * w, (0, pad_b))      # leader row must be w-masked
    oh = jax.nn.one_hot(assign_b, k, dtype=jnp.float32) * w[:, None]
    oh = _pad_to(_pad_to(oh, 1, 128), 0, row_mult)
    return d1, d2, oh, lg


def swap_g_stats(x: jnp.ndarray, y: jnp.ndarray, d1_b: jnp.ndarray,
                 d2_b: jnp.ndarray, assign_b: jnp.ndarray, w: jnp.ndarray,
                 k: int, lead_g: Optional[jnp.ndarray] = None,
                 *, metric: str = "l2", tm: int = 128,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused SWAP (FastPAM1) statistics: (Σg, Σg², Σg·g_lead), each [k, m]
    for the flattened arm set (medoid m_i, candidate x_j)."""
    if interpret is None:
        interpret = _default_interpret()
    m = x.shape[0]
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    yp = _pad_to(_pad_to(y, 1, 128), 0, 128)
    d1, d2, oh, lg = _swap_prep(d1_b, d2_b, assign_b, w, k, lead_g,
                                yp.shape[0] - y.shape[0])
    sums, sq, cross = _swap_g.swap_g_kernel(xp, yp, d1, d2, oh, lg,
                                            metric=metric, tm=tm,
                                            interpret=interpret)
    return sums[:m, :k].T, sq[:m, :k].T, cross[:m, :k].T


# Reference-axis tile budget for the cache-served SWAP kernel: one
# [128, CACHE_B_MAX] f32 distance tile is 1 MiB of VMEM.  The carried-
# statistic repair feeds the kernel the WHOLE capped PIC ring width
# (cache_width columns) as one batch; widths past the budget are split
# into additive chunks — Σg / Σg² / Σg·g_lead are sums over reference
# positions, so per-chunk results accumulate exactly.
CACHE_B_MAX = 2048


def swap_g_stats_cached(dxy: jnp.ndarray, d1_b: jnp.ndarray,
                        d2_b: jnp.ndarray, assign_b: jnp.ndarray,
                        w: jnp.ndarray, k: int,
                        lead_g: Optional[jnp.ndarray] = None,
                        *, tm: int = 128,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused SWAP statistics served from a PIC distance-cache block.

    Same contract as ``swap_g_stats`` but ``dxy`` ([m, B]) is a precomputed
    slice of the permutation-invariant column cache — this is the kernel
    behind warm (cached) bandit rounds and the carried-statistic repair of
    ``BanditPAM(reuse="pic")`` on TPU: zero fresh distance work, stats only.
    ``B`` may be the full capped cache width (``cache_width`` columns);
    past ``CACHE_B_MAX`` the reference axis is split into additive chunks
    so the resident tile stays VMEM-bounded.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, b = dxy.shape

    def one(dxy_c, d1_c, d2_c, a_c, w_c, lg_c):
        dp = _pad_to(_pad_to(dxy_c, 1, 128), 0, tm)
        d1, d2, oh, lg = _swap_prep(d1_c, d2_c, a_c, w_c, k, lg_c,
                                    dp.shape[1] - dxy_c.shape[1])
        return _swap_g.swap_g_from_cache_kernel(dp, d1, d2, oh, lg, tm=tm,
                                                interpret=interpret)

    if b <= CACHE_B_MAX:
        sums, sq, cross = one(dxy, d1_b, d2_b, assign_b, w, lead_g)
    else:
        sums = sq = cross = None
        # tracecheck: ignore[TRC002] -- trace-constant chunking over the
        # static cache width b (shape-derived); each chunk is one kernel
        # launch and the += merge order is fixed by the range().
        for lo in range(0, b, CACHE_B_MAX):
            hi = min(lo + CACHE_B_MAX, b)
            part = one(dxy[:, lo:hi], d1_b[lo:hi], d2_b[lo:hi],
                       assign_b[lo:hi], w[lo:hi],
                       None if lead_g is None else lead_g[lo:hi])
            if sums is None:
                sums, sq, cross = part
            else:
                sums, sq, cross = (sums + part[0], sq + part[1],
                                   cross + part[2])
    return sums[:m, :k].T, sq[:m, :k].T, cross[:m, :k].T


# ---------------------------------------------------------------------------
# Streaming g-stats megakernel wrappers (kernels/stream_g.py)
# ---------------------------------------------------------------------------

def _stream_tiles(n, d, k, tm, tb):
    """Resolve (tm, tb) through the backend-aware tuner when unset.
    Lazy import: ``repro.core.tuning`` is dependency-free, but going
    through the package keeps kernel import standalone."""
    from repro.core import tuning
    if tm is None or tb is None:
        cfg = tuning.resolve_tile_config(n, d, k, backend="pallas")
        tm = cfg.tm if tm is None else tm
        tb = cfg.tb if tb is None else tb
    return tm, tb


def _check_stream_d(d_pad: int, what: str) -> None:
    if d_pad > DK_MAX:
        raise ValueError(
            f"{what} holds both operand tiles feature-resident; padded "
            f"d={d_pad} exceeds the dk budget {DK_MAX} (g-statistics are "
            f"not additive across feature chunks) — use the tiled jnp "
            f"streaming path for wider features")


def stream_build_g_stats(x: jnp.ndarray, yref: jnp.ndarray,
                         dnear: jnp.ndarray, w: Optional[jnp.ndarray] = None,
                         lead_g: Optional[jnp.ndarray] = None,
                         *, metric: str = "l2", tm: Optional[int] = None,
                         tb: Optional[int] = None,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Streaming BUILD statistics over an UNBOUNDED reference set: one
    dispatch walks ``yref`` in ``tb``-tiles and accumulates (Σg, Σg²,
    Σg·g_lead) online — the exact-fallback pass (yref = the whole
    dataset) without any ``[m, chunk]`` HBM block."""
    if interpret is None:
        interpret = _default_interpret()
    m, d = x.shape
    r = yref.shape[0]
    tm, tb = _stream_tiles(m, d, 1, tm, tb)
    if w is None:
        w = jnp.ones((r,), jnp.float32)
    if lead_g is None:
        lead_g = jnp.zeros((r,), jnp.float32)
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    yp = _pad_to(_pad_to(yref, 1, 128), 0, tb)
    _check_stream_d(xp.shape[1], "stream_build_g_stats")
    pad_r = yp.shape[0] - r
    dn = jnp.pad(dnear, (0, pad_r))
    wp = jnp.pad(w, (0, pad_r))               # padded refs get weight 0
    lg = jnp.pad(lead_g, (0, pad_r))
    sums, sq, cross = _stream_g.stream_build_g_kernel(
        xp, yp, dn, wp, lg, metric=metric, tm=tm, tb=tb, interpret=interpret)
    return sums[:m], sq[:m], cross[:m]


def stream_swap_g_stats(x: jnp.ndarray, yref: jnp.ndarray, d1: jnp.ndarray,
                        d2: jnp.ndarray, assign: jnp.ndarray,
                        w: Optional[jnp.ndarray] = None, k: int = 1,
                        lead_g: Optional[jnp.ndarray] = None,
                        *, metric: str = "l2", tm: Optional[int] = None,
                        tb: Optional[int] = None,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Streaming SWAP (FastPAM1) statistics over an unbounded reference
    set; same contract as ``swap_g_stats`` ([k, m] outputs) with the
    reference walk replacing the resident batch."""
    if interpret is None:
        interpret = _default_interpret()
    m, d = x.shape
    r = yref.shape[0]
    tm, tb = _stream_tiles(m, d, k, tm, tb)
    if w is None:
        w = jnp.ones((r,), jnp.float32)
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    yp = _pad_to(_pad_to(yref, 1, 128), 0, tb)
    _check_stream_d(xp.shape[1], "stream_swap_g_stats")
    d1p, d2p, oh, lg = _swap_prep(d1, d2, assign, w, k, lead_g,
                                  yp.shape[0] - r, row_mult=tb)
    sums, sq, cross = _stream_g.stream_swap_g_kernel(
        xp, yp, d1p, d2p, oh, lg, metric=metric, tm=tm, tb=tb,
        interpret=interpret)
    return sums[:m, :k].T, sq[:m, :k].T, cross[:m, :k].T


def stream_top2(x: jnp.ndarray, med_pts: jnp.ndarray, *, metric: str = "l2",
                tm: Optional[int] = None,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Streaming nearest/second-nearest medoid reduction: ``[n, d]``
    points × ``[k, d]`` medoid rows → (d1[n], d2[n], assign[n] int32)
    with no ``[n, k]`` HBM block — the loss / assignment / serving pass.
    Ties resolve to the lowest medoid index (jnp.argmin's rule)."""
    if interpret is None:
        interpret = _default_interpret()
    n, d = x.shape
    k = med_pts.shape[0]
    tm, _ = _stream_tiles(n, d, k, tm, None)
    xp = _pad_to(_pad_to(x, 1, 128), 0, tm)
    mp = _pad_to(_pad_to(med_pts, 1, 128), 0, 128)
    _check_stream_d(xp.shape[1], "stream_top2")
    kmask = jnp.pad(jnp.ones((k,), jnp.float32), (0, mp.shape[0] - k))
    d1, d2, a = _stream_g.stream_top2_kernel(xp, mp, kmask, metric=metric,
                                             tm=tm, interpret=interpret)
    return d1[:n], d2[:n], a[:n]


def install(metrics=("l2", "l2sq", "cosine", "l1")) -> None:
    """Re-register core distance metrics to the kernel-backed paths
    (TPU deployment hook; a no-op semantically — same math)."""
    from repro.core import distances as core_distances

    for name in metrics:
        core_distances.register_metric(
            name, functools.partial(pairwise_distance, metric=name))
