# Pallas TPU kernels for the paper's compute hot-spot: distance/g-statistic
# evaluation (>=98% of BanditPAM wall clock).  Validated on CPU in
# interpret mode against ref.py; lowers to Mosaic on TPU.
from . import ops, ref
from .ops import (build_g_stats, install, pairwise_distance,
                  stream_build_g_stats, stream_swap_g_stats, stream_top2,
                  swap_g_stats, swap_g_stats_cached)

__all__ = ["ops", "ref", "pairwise_distance", "build_g_stats",
           "swap_g_stats", "swap_g_stats_cached", "stream_build_g_stats",
           "stream_swap_g_stats", "stream_top2", "install"]
