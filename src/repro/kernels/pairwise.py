"""Pallas TPU kernel: tiled pairwise dissimilarity.

TPU-native tiling (docs/design.md hardware adaptation #3):

* grid = (m/TM, r/TR); each program owns one [TM, TR] output tile.
* Feature dim D is resident in VMEM per tile (padded to a lane multiple of
  128).  VMEM budget at TM=TR=128, D=8192, f32: x-tile 4 MiB + y-tile
  4 MiB + out 64 KiB — comfortably under a v5e core's ~128 MiB VMEM; for
  larger D the ops wrapper splits the feature axis into ``dk``-column
  chunks and accumulates the additive per-chunk core (squared distances /
  abs-sums / dot products) across kernel calls (``ops.pairwise_distance``).
* MXU metrics (l2 / l2sq / cosine) are one ``dot_general`` with rank-1
  corrections: the [TM, D]x[D, TR] contraction is exactly the systolic
  array's shape (multiples of 128 on every matmul dim).
* L1 has no matmul form; it runs on the VPU with an in-register loop over
  D-chunks so the [TM, TR, chunk] broadcast temp stays ~512 KiB.

Zero-padding is free for every metric here: padded features contribute 0
to dots/norms/abs-sums, and padded rows/cols are cropped by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU_METRICS = ("l2", "l2sq", "cosine")
L1_CHUNK = 8


def dist_tile(x: jnp.ndarray, y: jnp.ndarray, metric: str) -> jnp.ndarray:
    """In-VMEM distance tile [TM, D] x [TR, D] -> [TM, TR] (f32 accum).

    ``"dot"`` is an internal metric (the raw MXU contraction) used by the
    ops wrapper to accumulate cosine similarities across feature chunks
    when D exceeds the VMEM tile budget; it is not registry-facing.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric in ("l2", "l2sq", "cosine", "dot"):
        xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        if metric == "dot":
            return xy
        if metric == "cosine":
            xn = jax.lax.rsqrt(jnp.maximum(jnp.sum(x * x, -1), 1e-30))
            yn = jax.lax.rsqrt(jnp.maximum(jnp.sum(y * y, -1), 1e-30))
            return 1.0 - xy * xn[:, None] * yn[None, :]
        d = jnp.maximum(jnp.sum(x * x, -1)[:, None]
                        + jnp.sum(y * y, -1)[None, :] - 2.0 * xy, 0.0)
        return jnp.sqrt(d) if metric == "l2" else d
    if metric == "l1":
        n_ch = x.shape[1] // L1_CHUNK

        def body(c, acc):
            xs = jax.lax.dynamic_slice_in_dim(x, c * L1_CHUNK, L1_CHUNK, 1)
            ys = jax.lax.dynamic_slice_in_dim(y, c * L1_CHUNK, L1_CHUNK, 1)
            return acc + jnp.sum(jnp.abs(xs[:, None, :] - ys[None, :, :]), -1)

        init = jnp.zeros((x.shape[0], y.shape[0]), jnp.float32)
        return jax.lax.fori_loop(0, n_ch, body, init)
    raise ValueError(f"unknown metric {metric}")


def _kernel(x_ref, y_ref, o_ref, *, metric):
    o_ref[...] = dist_tile(x_ref[...], y_ref[...], metric)


@functools.partial(jax.jit,
                   static_argnames=("metric", "tm", "tr", "interpret"))
def pairwise_kernel(x: jnp.ndarray, y: jnp.ndarray, *, metric: str,
                    tm: int = 128, tr: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """Pre-padded entry point: shapes must already be tile-aligned."""
    m, d = x.shape
    r = y.shape[0]
    assert m % tm == 0 and r % tr == 0 and d % 128 == 0, (m, r, d)
    grid = (m // tm, r // tr)
    return pl.pallas_call(
        functools.partial(_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tr), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, r), jnp.float32),
        interpret=interpret,
    )(x, y)
