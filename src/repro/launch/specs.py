"""ShapeDtypeStruct input specs and sharding assignment for every
(arch x shape) cell — the glue between configs, models, and the mesh.

``input_specs`` returns weak-type-correct, shardable stand-ins (no device
allocation); ``cell_shardings`` maps every leaf of (params, opt, batch,
state) to a NamedSharding derived from the parameter naming conventions.
"""

from __future__ import annotations

import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.train import OptConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.serve.lm import make_decode_step, make_prefill_step

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs; no allocation anywhere)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    b, l = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if shape.kind == "decode":
        if cfg.frontend == "audio_stub":
            return {"tokens": SDS((b, 1, cfg.n_codebooks), i32)}
        return {"tokens": SDS((b, 1), i32)}
    if cfg.frontend == "audio_stub":
        out = {"tokens": SDS((b, l, cfg.n_codebooks), i32),
               "labels": SDS((b, l, cfg.n_codebooks), i32)}
    elif cfg.frontend == "vision_stub":
        out = {"tokens": SDS((b, l - cfg.n_patches), i32),
               "patch_emb": SDS((b, cfg.n_patches, cfg.d_model), f32),
               "labels": SDS((b, l), i32)}
    else:
        out = {"tokens": SDS((b, l), i32), "labels": SDS((b, l), i32)}
    if shape.kind == "train":
        out["loss_mask"] = SDS((b, l), f32)
    else:                     # prefill uses tokens (+patches) only
        out.pop("labels")
    return out


def params_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: M.init_params(
        cfg, jax.random.PRNGKey(0), dtype=dtype))


def opt_specs(cfg: ArchConfig, params_tree, opt_cfg: OptConfig):
    return jax.eval_shape(lambda: init_opt_state(params_tree, opt_cfg))


def state_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: M.init_decode_state(
        cfg, shape.global_batch, shape.seq_len, dtype=dtype))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _axes(mesh: Mesh):
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    mp = "model" if "model" in names else None
    return dp, mp


# parameter path regex -> spec builder (dp=data axes, mp=model axis);
# first match wins, so the MoE (leading expert axis) rules come first.
_PARAM_RULES = [
    (r"moe.*\['wi'\]$",   lambda dp, mp: P(mp, None, None)),
    (r"moe.*\['wg'\]$",   lambda dp, mp: P(mp, None, None)),
    (r"moe.*\['wo'\]$",   lambda dp, mp: P(mp, None, None)),
    (r"\['router'\]$",    lambda dp, mp: P(None, None)),
    # attention / shared-attention projections
    (r"\['wq'\]$",        lambda dp, mp: P(None, mp)),
    (r"\['wk'\]$",        lambda dp, mp: P(None, None)),   # kv replicated (GQA)
    (r"\['wv'\]$",        lambda dp, mp: P(None, None)),
    (r"\['wo'\]$",        lambda dp, mp: P(mp, None)),
    # dense mlp
    (r"\['wi'\]$",        lambda dp, mp: P(None, mp)),
    (r"\['wg'\]$",        lambda dp, mp: P(None, mp)),
    # ssm
    (r"\['in_x'\]$",      lambda dp, mp: P(None, mp)),
    (r"\['in_z'\]$",      lambda dp, mp: P(None, mp)),
    (r"\['in_xbc'\]$",    lambda dp, mp: P(None, None)),   # mixed di+2st cols
    (r"\['in_dt'\]$",     lambda dp, mp: P(None, mp)),
    (r"\['x_proj'\]$",    lambda dp, mp: P(mp, None)),
    (r"\['dt_proj'\]$",   lambda dp, mp: P(None, mp)),
    (r"\['out_proj'\]$",  lambda dp, mp: P(mp, None)),
    # embeddings / heads
    (r"\['embed'\]$",     lambda dp, mp: P(mp, None)),
    (r"\['lm_head'\]$",   lambda dp, mp: P(None, mp)),
    (r"\['vision_proj'\]$", lambda dp, mp: P(None, None)),
]


def _param_spec(path_str: str, leaf, dp, mp, cfg: ArchConfig) -> P:
    ndim = len(leaf.shape)
    for pat, fn in _PARAM_RULES:
        if re.search(pat, path_str):
            spec = fn(dp, mp)
            base = list(spec)
            if "groups" in path_str:          # stacked [G, ...] leaves
                base = [None] + base
            if cfg.frontend == "audio_stub" and \
                    re.search(r"\['(embed|lm_head)'\]$", path_str):
                base = [None] + base          # leading codebook axis
            base = base[:ndim] + [None] * (ndim - len(base))
            return P(*base)
    return P(*([None] * ndim))                # norms, scalars, biases


def param_shardings(cfg: ArchConfig, params_tree, mesh: Mesh):
    dp, mp = _axes(mesh)

    def assign(path, leaf):
        return NamedSharding(mesh, _param_spec(
            jax.tree_util.keystr(path), leaf, dp, mp, cfg))

    return jax.tree_util.tree_map_with_path(assign, params_tree)


def opt_shardings(cfg: ArchConfig, opt_tree, mesh: Mesh):
    """Moments mirror the parameter shardings; step is replicated."""
    p_sh = param_shardings(cfg, opt_tree["m"], mesh)
    return {"m": p_sh, "v": param_shardings(cfg, opt_tree["v"], mesh),
            "step": NamedSharding(mesh, P())}


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, batch_tree,
                    mesh: Mesh):
    dp, mp = _axes(mesh)
    bspec = dp if shape.global_batch > 1 else None

    def assign(path, leaf):
        ndim = len(leaf.shape)
        return NamedSharding(mesh, P(bspec, *([None] * (ndim - 1))))

    return jax.tree_util.tree_map_with_path(assign, batch_tree)


def decode_state_shardings(cfg: ArchConfig, shape: ShapeConfig, state_tree,
                           mesh: Mesh):
    """Caches: [G, B, S, KVH, hd] — batch over data axes; the long-context
    (batch=1) cell shards the sequence axis over everything (SP decode);
    SSM states shard d_inner/heads over model."""
    dp, mp = _axes(mesh)
    long_ctx = shape.global_batch == 1
    all_axes = tuple(a for a in mesh.axis_names)

    def assign(path, leaf):
        ndim = len(leaf.shape)
        if ndim == 5:                                  # kv cache [G,B,S,KVH,hd]
            if long_ctx:
                return NamedSharding(mesh, P(None, None, all_axes, None, None))
            return NamedSharding(mesh, P(None, dp, mp, None, None))
        if ndim == 4:                                  # conv [G,B,K-1,C] or
            ps = "conv" if leaf.shape[2] <= 8 else None
            if ps == "conv":
                return NamedSharding(
                    mesh, P(None, None if long_ctx else dp, None, mp))
            return NamedSharding(mesh, P(None, None if long_ctx else dp, mp, None))
        if ndim == 3:
            return NamedSharding(mesh, P(None, None if long_ctx else dp, mp))
        # mamba2 h [G,B,nh,hd,st] is ndim 5 too — handled above by S-heur?
        return NamedSharding(mesh, P(*([None] * ndim)))

    def assign_safe(path, leaf):
        ndim = len(leaf.shape)
        # distinguish kv cache [G,B,S,KVH,hd] from mamba2 h [G,B,nh,hd,st]
        if ndim == 5 and leaf.shape[2] >= 512:         # big axis = sequence
            if long_ctx:
                return NamedSharding(mesh, P(None, None, all_axes, None, None))
            return NamedSharding(mesh, P(None, dp, mp, None, None))
        if ndim == 5:                                  # mamba2 state
            return NamedSharding(
                mesh, P(None, None if long_ctx else dp, mp, None, None))
        return assign(path, leaf)

    return jax.tree_util.tree_map_with_path(assign_safe, state_tree)


# ---------------------------------------------------------------------------
# cell assembly: (step_fn, example args, in/out shardings)
# ---------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               opt_cfg: Optional[OptConfig] = None):
    """Returns (fn, args, in_shardings, out_shardings) ready for
    jax.jit(fn, in_shardings=...).lower(*args)."""
    if opt_cfg is None:
        opt_cfg = OptConfig(moment_dtype=cfg.moment_dtype)
    repl = NamedSharding(mesh, P())
    p_specs = params_specs(cfg)
    p_sh = param_shardings(cfg, p_specs, mesh)
    b_specs = batch_specs(cfg, shape)
    b_sh = batch_shardings(cfg, shape, b_specs, mesh)

    if shape.kind == "train":
        o_specs = opt_specs(cfg, p_specs, opt_cfg)
        o_sh = opt_shardings(cfg, o_specs, mesh)
        fn = make_train_step(cfg, opt_cfg, microbatches=shape.microbatches)
        args = (p_specs, o_specs, b_specs)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh,
                  jax.tree.map(lambda _: repl,
                               {"loss": 0, "ce": 0, "aux": 0,
                                "grad_norm": 0, "lr": 0}))
        return fn, args, in_sh, out_sh

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, cache_len=shape.seq_len)
        s_specs = jax.eval_shape(fn, p_specs, b_specs)[1]
        s_sh = decode_state_shardings(cfg, shape, s_specs, mesh)
        dp, mp = _axes(mesh)
        if cfg.frontend == "audio_stub":      # logits [B, 1, nc, V]
            logits_sh = NamedSharding(mesh, P(dp, None, None, mp))
        else:
            logits_sh = NamedSharding(mesh, P(dp, None, mp))
        args = (p_specs, b_specs)
        return fn, args, (p_sh, b_sh), (logits_sh, s_sh)

    # decode
    fn = make_decode_step(cfg)
    s_specs = state_specs(cfg, shape)
    s_sh = decode_state_shardings(cfg, shape, s_specs, mesh)
    pos = SDS((), jnp.int32)
    dp, mp = _axes(mesh)
    long_ctx = shape.global_batch == 1
    if cfg.frontend == "audio_stub":
        logits_sh = NamedSharding(mesh, P(None if long_ctx else dp, None, None, mp))
    else:
        logits_sh = NamedSharding(mesh, P(None if long_ctx else dp, None, mp))
    args = (p_specs, s_specs, b_specs, pos)
    in_sh = (p_sh, s_sh, b_sh if not long_ctx else
             jax.tree.map(lambda _: repl, b_specs), repl)
    return fn, args, in_sh, (logits_sh, s_sh)
