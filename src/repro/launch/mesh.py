"""Production mesh definition.

A FUNCTION, not a module constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for subprocess tests (8 host devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
