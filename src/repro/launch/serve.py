"""Production serving driver: batched prefill + continuous greedy decode
with sharded caches, request batching, and simple latency accounting.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
        --requests 8 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shrules
from repro.models import model as M
from repro.runtime.elastic import build_mesh, plan_remesh
from repro.serve.lm import make_decode_step, make_prefill_step
from repro.train import synthetic_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n_dev = len(jax.devices())
    if n_dev > 1:
        plan = plan_remesh(n_dev, model_parallel=min(args.model_parallel, n_dev))
        shrules.set_mesh(build_mesh(plan))
        print(f"mesh: {plan.shape} {plan.axes}")

    dtype = jnp.float32 if n_dev == 1 else jnp.bfloat16
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    cache_len = args.prompt_len + args.max_new

    batch = synthetic_batch(cfg, args.requests, args.prompt_len, 0)
    prompts = {"tokens": batch["tokens"]}
    if "patch_emb" in batch:
        prompts["patch_emb"] = batch["patch_emb"]

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, state = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cfg.frontend == "audio_stub":
        tok = tok.reshape(args.requests, 1, cfg.n_codebooks)
    else:
        tok = tok.reshape(args.requests, 1)

    lat = []
    out = [tok]
    for i in range(args.max_new - 1):
        t1 = time.time()
        logits, state = decode(params, state, {"tokens": tok},
                               jnp.int32(args.prompt_len + i))
        jax.block_until_ready(logits)
        lat.append(time.time() - t1)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)

    lat_sorted = sorted(lat[1:]) or [0.0]
    p50 = lat_sorted[len(lat_sorted) // 2]
    p99 = lat_sorted[min(len(lat_sorted) - 1, int(len(lat_sorted) * 0.99))]
    print(f"prefill: {t_prefill*1e3:.0f} ms (incl. compile) for "
          f"{args.requests}x{args.prompt_len}")
    print(f"decode:  p50 {p50*1e3:.1f} ms/step, p99 {p99*1e3:.1f} ms/step, "
          f"throughput {args.requests/max(p50,1e-9):.0f} tok/s steady-state")


if __name__ == "__main__":
    main()
