"""Production training driver: mesh construction, sharded state, data
pipeline, fault-tolerant loop with checkpoint/resume.

On real hardware (multi-host):  python -m repro.launch.train --arch <id>
On this container it drives reduced configs on one device — same code
path, smaller mesh (the 16x16 / 2x16x16 configuration is exercised by
the dry-run, which this driver shares its cell-assembly with).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.distributed import sharding as shrules
from repro.models import model as M
from repro.runtime.elastic import build_mesh, plan_remesh
from repro.runtime.fault import FaultTolerantLoop
from repro.train import (DataPipeline, OptConfig, init_opt_state,
                         make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        plan = plan_remesh(n_dev, model_parallel=min(args.model_parallel, n_dev))
        mesh = build_mesh(plan)
        shrules.set_mesh(mesh)
        print(f"mesh: {plan.shape} {plan.axes} (dropped {plan.dropped_chips})")

    dtype = jnp.float32 if n_dev == 1 else jnp.bfloat16
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    ocfg = OptConfig(lr=1e-3, warmup_steps=20, moment_dtype=cfg.moment_dtype)
    opt = init_opt_state(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg, args.microbatches))
    pipe = DataPipeline(cfg, args.batch, args.seq)

    loop = FaultTolerantLoop(args.ckpt_dir, save_every=args.save_every)
    state = {"params": params, "opt": opt}
    state, start = loop.restore_or(state)
    pipe.step = start
    if start:
        print(f"resumed at step {start}")

    t0 = time.time()

    def one_step(st, i):
        batch = next(pipe)
        p, o, m = step_fn(st["params"], st["opt"], batch)
        if i % 10 == 0:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0):.0f}s)", flush=True)
        return {"params": p, "opt": o}, m

    loop.run(state, one_step, n_steps=args.steps, start_step=start)
    print("training complete")


if __name__ == "__main__":
    main()
