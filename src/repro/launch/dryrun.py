"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract the roofline inputs (HLO FLOPs / bytes, per-collective traffic,
per-device memory) — proof the distribution config is coherent without
real hardware.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_1_7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results append incrementally to results/dryrun.json (one JSON object per
line), so a crashed batch resumes where it left off.
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production meshes.  MUST precede any jax
# import, including the repro ones below.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, supports_long_context
from repro.distributed import sharding as shrules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(tystr: str) -> int:
    m = _TYPE_RE.match(tystr)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_COLL_RE = re.compile(
    r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def collective_bytes(hlo_text: str):
    """Sum RESULT bytes of every collective op in the (per-device,
    post-SPMD) HLO module, by op kind.  Post-opt HLO references operands
    as bare %names, so the result type (possibly a tuple) is the reliable
    size source; for all-reduce it equals the shard size, for all-gather
    it is the gathered size — a consistent upper bound on wire traffic."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_ty, kind = m.groups()
        nbytes = sum(_type_bytes(f"{dt}[{dims}]")
                     for dt, dims in _TYPE_RE.findall(result_ty))
        out[kind] += nbytes
        counts[kind] += 1
    return out, counts


def _compile_once(cfg, shape, mesh):
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh)
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
    return lowered.compile()


def _measure(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    cbytes, ccounts = collective_bytes(hlo)
    return {"flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "collective_bytes": cbytes, "collective_counts": ccounts}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             surrogates: bool = True) -> dict:
    """Compile the full cell (memory proof) plus — on the single-pod mesh —
    two reduced-depth surrogates (1 and 2 layer groups) whose difference
    gives the per-group cost.  cost_analysis counts a lax.scan body ONCE,
    so the loop-corrected per-device totals are

        total = microbatches x (A + (G - 1) x (B - A))       (+opt, <<1%)

    where A/B are the 1-/2-group measurements with identical batch shapes.
    The attention kv loop is unrolled during the dry-run (layers.UNROLL_KV)
    so intra-group costs carry no hidden loops either.
    """
    import dataclasses

    from repro.models import layers as L

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if shape_name == "long_500k" and not supports_long_context(cfg):
        rec["status"] = "skipped (pure full attention)"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {}
    if shape_name == "long_500k":
        rules = {"batch": None, "kv_seq": tuple(mesh.axis_names)}
    shrules.set_mesh(mesh, rules)
    L.UNROLL_KV = True
    try:
        t0 = time.time()
        compiled = _compile_once(cfg, shape, mesh)
        rec["compile_s"] = round(time.time() - t0, 1)
        rec.update(_measure(compiled))
        try:
            mem = compiled.memory_analysis()
            rec["mem"] = {
                "arg_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "out_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes", -1),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
            }
        except Exception as e:          # backend may not support it
            rec["mem"] = {"error": str(e)}
        del compiled

        if surrogates and not multi_pod:
            from repro.models import model as Mmod
            p = len(cfg.layer_pattern)
            g = cfg.n_groups
            m = shape.microbatches if shape.kind == "train" else 1
            # one-microbatch worth of batch, no scans anywhere
            s_shape = dataclasses.replace(
                shape, global_batch=shape.global_batch // m, microbatches=1)
            Mmod.UNROLL_SCANS = True
            try:
                a = _measure(_compile_once(
                    dataclasses.replace(cfg, n_layers=p), s_shape, mesh))
                b = _measure(_compile_once(
                    dataclasses.replace(cfg, n_layers=2 * p), s_shape, mesh))
            finally:
                Mmod.UNROLL_SCANS = False

            def corrected(key):
                if isinstance(a[key], dict):
                    return {kk: m * (a[key][kk] + (g - 1) * (b[key][kk] - a[key][kk]))
                            for kk in a[key]}
                return m * (a[key] + (g - 1) * (b[key] - a[key]))

            rec["corrected"] = {
                "flops": corrected("flops"),
                "bytes_accessed": corrected("bytes_accessed"),
                "collective_bytes": corrected("collective_bytes"),
            }
        pc = cfg.param_count()
        rec["params_total"] = pc["total"]
        rec["params_active"] = pc["active"]
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
    finally:
        L.UNROLL_KV = False
        shrules.clear()
    return rec


def load_done(path: str):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped (pure full attention)"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    done = set() if args.force else load_done(args.out)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) in done:
                    print(f"[skip-done] {arch} {shape} {mesh_name}", flush=True)
                    continue
                print(f"[run] {arch} {shape} {mesh_name}", flush=True)
                rec = run_cell(arch, shape, mp)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                msg = rec["status"]
                if rec["status"] == "ok":
                    msg += (f" flops={rec['flops']:.3e}"
                            f" compile={rec['compile_s']}s")
                elif rec["status"] == "error":
                    msg += " :: " + rec["error"][:200]
                print(f"  -> {msg}", flush=True)


if __name__ == "__main__":
    main()
