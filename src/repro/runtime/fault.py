"""Fault-tolerant training loop: periodic checkpoints, preemption-signal
handling, bounded retry on transient step failures, straggler detection.

Designed for the 1000+-node regime (docs/design.md §6): the data pipeline is
step-indexed and deterministic, so recovery = restore latest checkpoint +
fast-forward the step counter.  Nothing here is CPU-container-specific —
the same loop drives the multi-host launcher.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from . import checkpoint as ckpt


@dataclass
class StragglerMonitor:
    """Tracks per-host step durations; flags hosts persistently slower than
    `factor` x the p50.  The launcher replaces flagged hosts; with a
    deterministic pipeline the replacement resumes from the checkpoint."""
    factor: float = 2.0
    window: int = 20
    history: Dict[int, List[float]] = field(default_factory=dict)

    def record(self, host: int, dt: float) -> None:
        self.history.setdefault(host, []).append(dt)
        self.history[host] = self.history[host][-self.window:]

    def stragglers(self) -> List[int]:
        if not self.history:
            return []
        medians = {h: float(np.median(v)) for h, v in self.history.items()}
        p50 = float(np.median(list(medians.values())))
        return [h for h, m in medians.items()
                if m > self.factor * p50 and len(self.history[h]) >= 3]


class Preemption(Exception):
    pass


class FaultTolerantLoop:
    def __init__(self, ckpt_dir: str, save_every: int = 50,
                 max_retries: int = 3, install_sigterm: bool = True):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.monitor = StragglerMonitor()
        self._preempted = False
        if install_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass  # not on main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted = True

    # -- state = {"params": ..., "opt": ..., } --------------------------------
    def restore_or(self, state: Any, shardings: Any = None):
        """Resume from the latest checkpoint if one exists."""
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return state, 0
        restored, meta = ckpt.restore(self.ckpt_dir, state, step=step,
                                      shardings=shardings)
        return restored, meta["step"]

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable] = None) -> Any:
        """Run `step_fn(state, step) -> (state, metrics)` with checkpoints.

        Transient exceptions retry the *same* step after restoring the
        last checkpoint (deterministic data ⇒ bit-exact replay); SIGTERM
        checkpoints and raises Preemption.
        """
        step = start_step
        retries = 0
        while step < n_steps:
            if self._preempted:
                ckpt.save(self.ckpt_dir, step, state, extra={"reason": "preempt"})
                raise Preemption(f"preempted at step {step}")
            t0 = time.monotonic()
            try:
                state, metrics = step_fn(state, step)
            except Exception:
                retries += 1
                if retries > self.max_retries:
                    raise
                last = ckpt.latest_step(self.ckpt_dir)
                if last is not None:
                    state, meta = ckpt.restore(self.ckpt_dir, state, step=last)
                    step = meta["step"]
                continue
            retries = 0
            self.monitor.record(0, time.monotonic() - t0)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % self.save_every == 0:
                ckpt.save(self.ckpt_dir, step, state)
        return state
