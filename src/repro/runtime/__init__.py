"""``repro.runtime`` — checkpoint/runtime support for the clustering engine.

Only :mod:`.checkpoint` (bit-exact snapshot/resume, used by the serving
layer) is part of the product surface.  The elastic-reshard and
fault-tolerance scaffolding for the dormant LM training arc is
quarantined in :mod:`.elastic` / :mod:`.fault` — import those
explicitly; they are intentionally NOT loaded from the package front
(docs/design.md #9, mirroring ``repro.serve.lm``).
"""

from . import checkpoint

__all__ = ["checkpoint"]
