from . import checkpoint, elastic, fault
from .fault import FaultTolerantLoop, Preemption, StragglerMonitor

__all__ = ["checkpoint", "elastic", "fault", "FaultTolerantLoop",
           "Preemption", "StragglerMonitor"]
