from . import checkpoint, elastic, fault
from .fault import FaultTolerantLoop, Preemption, StragglerMonitor
