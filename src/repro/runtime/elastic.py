"""Elastic re-meshing: choose a production mesh for whatever host set
survives, and re-shard a checkpoint onto it.

Policy (docs/design.md §6): the model axis is sacred (TP extent fixed by the
config's divisibility constraints); failures shrink the data/pod axes.
Checkpoints store global shapes, so re-sharding is `device_put` with the
new shardings — no resharding pass needed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_chips: int


def plan_remesh(chips_alive: int, model_parallel: int = 16,
                pods: Optional[int] = None) -> MeshPlan:
    """Largest (pod?, data, model) mesh fitting the surviving chips.

    data extent is the largest power of two such that
    pods*data*model <= chips_alive (power-of-two keeps batch divisibility
    with the standard global-batch choices).
    """
    if chips_alive < model_parallel:
        raise ValueError(f"need >= {model_parallel} chips, have {chips_alive}")
    if pods is not None and pods > 1:
        per_pod = chips_alive // pods
        data = 1
        while pods * (data * 2) * model_parallel <= chips_alive and \
                (data * 2) * model_parallel <= per_pod * model_parallel:
            data *= 2
        while pods * data * model_parallel > chips_alive:
            data //= 2
        if data < 1:
            raise ValueError("not enough chips for requested pod count")
        used = pods * data * model_parallel
        return MeshPlan((pods, data, model_parallel), ("pod", "data", "model"),
                        chips_alive - used)
    data = 1
    while (data * 2) * model_parallel <= chips_alive:
        data *= 2
    used = data * model_parallel
    return MeshPlan((data, model_parallel), ("data", "model"),
                    chips_alive - used)


def build_mesh(plan: MeshPlan) -> jax.sharding.Mesh:
    return jax.make_mesh(
        plan.shape, plan.axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(plan.axes))
