"""Sharded checkpoint save/restore — no orbax/tensorstore dependency.

Layout:  <dir>/step_<N>/
            manifest.msgpack     — treedef, shapes, dtypes, step, extras
            arr_<i>.npy          — one file per leaf (host-local full value
                                   in this single-process container; on a
                                   multi-host deployment each host writes
                                   its addressable shards with the same
                                   manifest, keyed by process index)

Checkpoints are **mesh-shape-agnostic**: leaves are stored with their
global shapes; ``restore`` device_puts onto whatever shardings the caller
provides, so restoring onto a different mesh (elastic resize) is just a
different `shardings` argument (tested in tests/test_runtime.py).
"""

from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None
         ) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    keys, leaves, _ = _leaf_paths(tree)
    meta = {"step": step, "keys": keys, "extra": extra or {},
            "shapes": [], "dtypes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        meta["shapes"].append(list(arr.shape))
        meta["dtypes"].append(str(arr.dtype))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)                      # atomic publish
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `tree_like`; `shardings` may be a
    matching pytree of NamedShardings (or None for host-local arrays).

    Leaf dtype discipline: a leaf whose template is a **numpy** array or
    scalar is returned as numpy with the SAVED bits untouched — host-side
    state (f64 reservoir keys, i64 counters) must round-trip exactly even
    though jax's default f32 regime would silently downcast it.  Device
    templates (jax arrays) keep the historical behaviour: cast to the
    template dtype on device (or ``device_put`` onto the given sharding).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    keys, leaves, treedef = _leaf_paths(tree_like)
    assert keys == meta["keys"], "checkpoint/model structure mismatch"
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: x is None)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        elif isinstance(ref, (np.ndarray, np.generic)):
            out.append(arr.astype(ref.dtype, copy=False))
        else:
            out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), meta


def read_extra(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    if step is None:
        step = latest_step(ckpt_dir)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())["extra"]
