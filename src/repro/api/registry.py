"""Solver registry for the ``KMedoids`` facade.

Mirrors the ``repro.core.distances`` metric-registry pattern: an open
string-keyed table, so each new k-medoids algorithm (the solver space keeps
growing — FasterPAM 2019, BanditPAM++ 2023, OneBatchPAM 2025, ...) slots in
as one registered function instead of a new public entrypoint.

Solver contract::

    fn(data, k, *, metric: str, seed: int, **params) -> FitReport

``data`` is a ``[n, d]`` float32 array (already ``attach_index``-augmented
when ``metric == "precomputed"``); ``metric`` is a REGISTERED name (the
facade resolves callables first); ``params`` are solver-specific knobs
passed through from ``KMedoids(**solver_params)``.  The returned
``FitReport`` must carry medoids, loss, and the fresh/cached
distance-evaluation ledger; ``labels`` / ``solver`` / ``metric`` fields are
filled by the facade.

``banditpam_dist`` is the sharded solver (``repro.core.distributed``): it
additionally takes ``mesh=`` (a ``jax.sharding.Mesh`` whose axis names
include ``"data"`` and/or ``"pod"``; defaults to a 1-D data mesh over
every local device) and, like the other bandit solvers, the ``backend=``
stats-backend kwarg plus the BanditPAM++ reuse knobs (``reuse="pic"``
for the mesh-sharded PIC column ring, ``cache_width=`` for its bounded
width — see ``repro.core.pic_cache``).
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.core.banditpam import BanditPAM
from repro.core.baselines import clara, clarans, fasterpam, voronoi_iteration
from repro.core.pam import pam
from repro.core.report import FitReport

Solver = Callable[..., FitReport]

_SOLVERS: Dict[str, Solver] = {}
_BATCH_SOLVERS: Dict[str, Callable] = {}
_ACCEPTS_BACKEND: set = set()


def register_solver(name: str, fn: Solver, *,
                    accepts_backend: bool = False,
                    batch_fn: Callable = None) -> None:
    """Register ``fn`` under ``name``.  ``accepts_backend=True`` declares
    that the solver takes the ``backend=`` stats-backend kwarg
    (``repro.core.engine``) — the facade only forwards ``KMedoids(backend=…)``
    to solvers that opted in.

    ``batch_fn`` (optional) is the solver's batched multi-fit entrypoint
    backing ``KMedoids.fit_batch``, with the contract::

        batch_fn(datasets, k, *, metric, seed, seeds=None, **params)
            -> BatchFitReport

    ``datasets`` is a ``[B, n, d]`` array or list of ragged ``[n_i, d]``
    arrays; each fit in the returned batch must reproduce ``fn`` on the
    same dataset/seed bit-identically (medoids, loss, ledger) — the
    invariant ``tests/test_multifit.py`` enforces for the bandit solvers.
    """
    _SOLVERS[name] = fn
    if batch_fn is not None:
        _BATCH_SOLVERS[name] = batch_fn
    else:
        _BATCH_SOLVERS.pop(name, None)
    if accepts_backend:
        _ACCEPTS_BACKEND.add(name)
    else:
        _ACCEPTS_BACKEND.discard(name)


def get_solver(name: str) -> Solver:
    if name not in _SOLVERS:
        raise KeyError(f"unknown solver {name!r}; have {sorted(_SOLVERS)}")
    return _SOLVERS[name]


def get_batch_solver(name: str) -> Callable:
    get_solver(name)                       # unknown-name error first
    if name not in _BATCH_SOLVERS:
        raise ValueError(
            f"solver {name!r} has no batched entrypoint; fit_batch is "
            f"available for {sorted(_BATCH_SOLVERS)} (register one via "
            f"register_solver(..., batch_fn=...))")
    return _BATCH_SOLVERS[name]


def available_solvers():
    return sorted(_SOLVERS)


def available_batch_solvers():
    return sorted(_BATCH_SOLVERS)


def solver_accepts_backend(name: str) -> bool:
    return name in _ACCEPTS_BACKEND


# Solvers that accept the adaptive-search knobs (baseline / sampling /
# cache_cols / ...).
BANDIT_SOLVERS = ("banditpam", "banditpam_pp")


def default_params(solver: str) -> dict:
    """Recommended ``solver_params`` for a solver — the single source the
    examples and benchmarks draw from, so a newly registered solver is
    configured in one place.  The bandit solvers get the leader
    control variate (the repo's best configuration); everything else runs
    stock."""
    return {"baseline": "leader"} if solver in BANDIT_SOLVERS else {}


# ---------------------------------------------------------------------------
# Built-in solvers — thin adapters over the legacy entrypoints, so
# KMedoids(solver=s) is evaluation-for-evaluation identical to calling them.
# ---------------------------------------------------------------------------

def _banditpam(data, k, *, metric, seed, **params):
    return BanditPAM(k, metric=metric, seed=seed, **params).fit(data)


def _banditpam_batch(datasets, k, *, metric, seed, seeds=None, **params):
    return BanditPAM(k, metric=metric, seed=seed,
                     **params).fit_batch(datasets, seeds=seeds)


def _banditpam_pp(data, k, *, metric, seed, **params):
    # BanditPAM++ = the SWAP-phase reuse engine (virtual arms over the
    # permutation-invariant distance cache).
    params.setdefault("reuse", "pic")
    return BanditPAM(k, metric=metric, seed=seed, **params).fit(data)


def _banditpam_pp_batch(datasets, k, *, metric, seed, seeds=None, **params):
    params.setdefault("reuse", "pic")
    return BanditPAM(k, metric=metric, seed=seed,
                     **params).fit_batch(datasets, seeds=seeds)


def _banditpam_dist(data, k, *, metric, seed, **params):
    # Sharded BanditPAM over a device mesh (stratified per-shard reference
    # sampling, psum-composed StatsBackend statistics).  Imported lazily so
    # the registry stays import-light when the solver is never used.
    from repro.core.distributed import DistributedBanditPAM, default_mesh
    mesh = params.pop("mesh", None)
    if mesh is None:
        mesh = default_mesh()
    return DistributedBanditPAM(k, mesh, metric=metric, seed=seed,
                                **params).fit(data)


def _pam(data, k, *, metric, seed, **params):
    # Deterministic; seed intentionally unused.
    return pam(data, k, metric=metric, fastpam1=False, **params)


def _fastpam1(data, k, *, metric, seed, **params):
    # Identical medoids to PAM; n² (not k·n²) SWAP accounting.
    return pam(data, k, metric=metric, fastpam1=True, **params)


def _fasterpam(data, k, *, metric, seed, **params):
    return fasterpam(data, k, metric=metric, seed=seed, **params)


def _clara(data, k, *, metric, seed, **params):
    return clara(data, k, metric=metric, seed=seed, **params)


def _clarans(data, k, *, metric, seed, **params):
    return clarans(data, k, metric=metric, seed=seed, **params)


def _voronoi(data, k, *, metric, seed, **params):
    return voronoi_iteration(data, k, metric=metric, seed=seed, **params)


def _onebatchpam(data, k, *, metric, seed, **params):
    # OneBatchPAM: k-medoids against ONE fixed reference batch — no bandit
    # loop, one [n, b] kernel residency.  The latency-floor fast path the
    # streaming MedoidService refits through; ``init=`` warm-starts SWAP
    # from current medoids.  Imported lazily like banditpam_dist.
    from repro.core.onebatch import onebatchpam
    return onebatchpam(data, k, metric=metric, seed=seed, **params)


register_solver("banditpam", _banditpam, accepts_backend=True,
                batch_fn=_banditpam_batch)
register_solver("banditpam_pp", _banditpam_pp, accepts_backend=True,
                batch_fn=_banditpam_pp_batch)
register_solver("banditpam_dist", _banditpam_dist, accepts_backend=True)
register_solver("pam", _pam)
register_solver("fastpam1", _fastpam1)
register_solver("fasterpam", _fasterpam)
register_solver("clara", _clara)
register_solver("clarans", _clarans)
register_solver("voronoi", _voronoi)
register_solver("onebatchpam", _onebatchpam, accepts_backend=True)
