"""``KMedoids`` — the one estimator fronting every solver in the repo.

scikit-learn-style surface::

    from repro.api import KMedoids

    est = KMedoids(k=5, solver="banditpam", metric="l2", seed=0)
    est.fit(X)                      # X: [n, d]
    est.medoids_                    # [k] indices into X
    est.labels_                     # [n] in-sample assignment
    est.loss_                       # sum of nearest-medoid dissimilarities
    est.report_                     # the solver's full FitReport (ledger etc.)
    est.predict(X_new)              # [m] nearest-medoid labels
    est.transform(X_new)            # [m, k] dissimilarities to the medoids

``solver`` is any name in ``available_solvers()`` (extendable via
``register_solver``); ``metric`` is a registered name, a raw
``[m,d]x[r,d]->[m,r]`` callable (auto-registered), or ``"precomputed"``.

With ``metric="precomputed"``, ``fit`` takes the ``[n, n]`` dissimilarity
matrix itself, and ``predict``/``transform`` take the ``[m, n]``
query-to-fit-points dissimilarity block — out-of-sample inference then
reduces to selecting the fitted medoid columns.

Unlike the legacy ``BanditPAM.fit_predict`` (which returns a
``(FitReport, labels)`` tuple), ``KMedoids.fit_predict`` follows the
sklearn convention and returns labels only.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.banditpam import medoid_cache
from repro.core.distances import attach_index, resolve_metric

from .predict import DEFAULT_CHUNK, medoid_distances
from .registry import (get_batch_solver, get_solver,
                       solver_accepts_backend)


def _pad_batch(X_batch) -> jnp.ndarray:
    """Stack a (possibly ragged) list of [n_i, d] arrays into one padded
    [B, n_max, d] device array (zero pad rows)."""
    if not isinstance(X_batch, (list, tuple)):
        return jnp.asarray(np.asarray(X_batch, np.float32))
    arrs = [np.asarray(x, np.float32) for x in X_batch]
    n_max = max(x.shape[0] for x in arrs)
    out = np.zeros((len(arrs), n_max, arrs[0].shape[1]), np.float32)
    for i, x in enumerate(arrs):
        out[i, : x.shape[0]] = x
    return jnp.asarray(out)


@functools.partial(jax.jit, static_argnames=("metric",))
def _batch_labels(data, medoids, *, metric: str):
    """In-sample assignments for a batch of fits: ONE dispatch, lax.map
    over the padded [B, n_max, d] lanes — the same per-lane math as the
    single-fit facade's ``medoid_cache`` call (pad rows get arbitrary
    labels; callers mask with ``n_valid``)."""
    def lane(xs):
        _, _, assign = medoid_cache(xs[0], xs[1], metric=metric)
        return assign

    return jax.lax.map(lane, (data, medoids))


class KMedoids:
    """k-medoids clustering through the solver registry.

    Args:
      k: number of medoids.
      solver: registered solver name (``available_solvers()``).
      metric: registered metric name, callable, or ``"precomputed"``.
      seed: forwarded to stochastic solvers (deterministic ones ignore it).
      backend: ``"auto"`` | ``"pallas"`` | ``"jnp"`` (or any registered
        stats backend) — which g-statistics path the *fit* runs through
        (``repro.core.engine``).  Forwarded to solvers registered with
        ``accepts_backend=True`` (the bandit solvers); other solvers
        require the default ``"auto"``.
      predict_backend: ``"auto"`` | ``"pallas"`` | ``"jnp"`` — which pairwise
        path scores out-of-sample points (overridable per call).
      predict_chunk: query rows per dispatch in predict/transform, bounding
        the resident ``[chunk, k]`` block.
      **solver_params: passed through to the solver (e.g. ``reuse="pic"``,
        ``cache_width=...`` to cap the PIC column ring,
        ``baseline="leader"``, ``max_neighbors=...``; for
        ``solver="banditpam_dist"``, ``mesh=`` selects the device mesh the
        sharded fit runs on — default: every local device — and
        ``reuse="pic"`` enables the mesh-sharded PIC cache).
    """

    def __init__(self, k: int, solver: str = "banditpam", metric="l2",
                 seed: int = 0, backend: str = "auto",
                 predict_backend: str = "auto",
                 predict_chunk: int = DEFAULT_CHUNK, **solver_params):
        if int(k) < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = int(k)
        self.solver = solver
        self.metric = metric
        self.seed = int(seed)
        self.backend = backend
        self.predict_backend = predict_backend
        self.predict_chunk = int(predict_chunk)
        self.solver_params = dict(solver_params)
        # fitted state
        self.report_ = None
        self.medoids_ = None
        self.labels_ = None
        self.loss_ = None

    def __repr__(self):
        extra = "".join(f", {k}={v!r}" for k, v in self.solver_params.items())
        return (f"KMedoids(k={self.k}, solver={self.solver!r}, "
                f"metric={self.metric!r}, seed={self.seed}{extra})")

    # -- fitting ---------------------------------------------------------
    def fit(self, X) -> "KMedoids":
        solver_fn = get_solver(self.solver)        # fail fast on bad names
        metric_name = resolve_metric(self.metric)
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected [n, d] data, got shape {X.shape}")
        if X.shape[0] <= self.k:
            raise ValueError(f"need n > k, got n={X.shape[0]}, k={self.k}")
        if metric_name == "precomputed":
            data = attach_index(X)                 # validates squareness
        else:
            data = jnp.asarray(X)
        params = dict(self.solver_params)
        if solver_accepts_backend(self.solver):
            params.setdefault("backend", self.backend)
        elif self.backend != "auto":
            raise ValueError(
                f"solver {self.solver!r} does not take a stats backend; "
                f"backend={self.backend!r} only applies to solvers "
                f"registered with accepts_backend=True")
        report = solver_fn(data, self.k, metric=metric_name, seed=self.seed,
                           **params)
        medoids = np.asarray(report.medoids).astype(np.int64)
        # In-sample labels under the SAME metric the solver used (for
        # "precomputed" that is the matrix-lookup metric over `data`).
        _, _, assign = medoid_cache(data, jnp.asarray(medoids, jnp.int32),
                                    metric=metric_name)
        report.labels = np.asarray(assign)
        report.solver = self.solver
        report.metric = metric_name
        self.report_ = report
        self.medoids_ = medoids
        self.labels_ = report.labels
        self.loss_ = float(report.loss)
        self._metric_name = metric_name
        self._n_fit = X.shape[0]
        if metric_name == "precomputed":
            self._medoid_points = None
            self.n_features_in_ = X.shape[1]
        else:
            self._medoid_points = jnp.asarray(X[medoids])
            self.n_features_in_ = X.shape[1]
        return self

    def fit_batch(self, X_batch, seeds=None):
        """Fit a batch of INDEPENDENT datasets in one dispatch per phase.

        ``X_batch`` is a ``[B, n, d]`` array or a list of ``[n_i, d]``
        arrays (ragged n is padded and masked internally); ``seeds`` an
        optional length-B list of per-fit RNG seeds (default: every fit
        uses ``self.seed``).  Only batch-capable solvers are eligible
        (``banditpam`` / ``banditpam_pp`` — see ``register_solver``'s
        ``batch_fn``); each fit in the batch reproduces the single-fit
        ``fit`` bit-identically for the same seed (medoids, loss,
        fresh/cached ledger).

        Returns a :class:`repro.core.report.BatchFitReport` with per-fit
        ``FitReport``s, stacked medoids/loss/labels, and the measured
        batch-level ``dispatches_by_phase`` (one jit per phase).  Does
        NOT set the single-fit fitted state (``medoids_`` etc.) — a
        batch has no single in-sample assignment for ``predict``.
        """
        batch_fn = get_batch_solver(self.solver)   # fail fast on bad names
        metric_name = resolve_metric(self.metric)
        if metric_name == "precomputed":
            raise ValueError("fit_batch does not support "
                             "metric='precomputed' (per-fit dissimilarity "
                             "matrices would be ragged); pass features")
        params = dict(self.solver_params)
        if solver_accepts_backend(self.solver):
            params.setdefault("backend", self.backend)
        report = batch_fn(X_batch, self.k, metric=metric_name,
                          seed=self.seed, seeds=seeds, **params)
        # Stacked in-sample labels: one jit, lax.map over the padded
        # lanes (pad rows get arbitrary labels; mask with n_valid).
        report.labels = np.asarray(_batch_labels(
            _pad_batch(X_batch), jnp.asarray(report.medoids, jnp.int32),
            metric=metric_name))
        report.solver = self.solver
        report.metric = metric_name
        return report

    def _check_fitted(self):
        if self.report_ is None:
            raise ValueError("this KMedoids instance is not fitted yet; "
                             "call fit(X) first")

    # -- inference -------------------------------------------------------
    def transform(self, X, backend: Optional[str] = None) -> np.ndarray:
        """Dissimilarities from each query row to the fitted medoids, [m, k].

        With ``metric="precomputed"``, ``X`` is the ``[m, n_fit]``
        query-to-fit-points dissimilarity block.
        """
        self._check_fitted()
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected 2-D queries, got shape {X.shape}")
        if self._metric_name == "precomputed":
            if X.shape[1] != self._n_fit:
                raise ValueError(
                    f"precomputed queries must be [m, n_fit={self._n_fit}] "
                    f"dissimilarities to the fit points, got {X.shape}")
            return X[:, self.medoids_]
        if X.shape[1] != self.n_features_in_:
            raise ValueError(f"query feature dim {X.shape[1]} != fitted "
                             f"{self.n_features_in_}")
        return medoid_distances(
            X, self._medoid_points, self._metric_name,
            backend=self.predict_backend if backend is None else backend,
            chunk=self.predict_chunk)

    def predict(self, X, backend: Optional[str] = None) -> np.ndarray:
        """Nearest-medoid label (0..k-1) for each query row."""
        return np.argmin(self.transform(X, backend=backend), axis=1)

    # -- sklearn conveniences -------------------------------------------
    def fit_predict(self, X) -> np.ndarray:
        """Fit and return the in-sample labels (labels ONLY — sklearn
        convention, unlike the legacy ``BanditPAM.fit_predict`` tuple)."""
        return self.fit(X).labels_

    def fit_transform(self, X) -> np.ndarray:
        return self.fit(X).transform(X)
