"""Batched out-of-sample inference against fitted medoids.

New points never touch the solvers.  Assignment (:func:`assign_medoids`)
is one streaming dispatch through the backend's top-2 contract
(``StatsBackend.top2``, docs/design.md #8): the ``[m, k]`` distance block
is reduced tile-by-tile as it is produced and never materialised, so
there is no query-chunk loop and memory stays linear in m.  Full distance
*matrices* (:func:`medoid_distances`, where the block IS the product) are
still chunked over the query axis so the resident block never exceeds
``chunk × max(k, d)`` — on TPU that keeps each Pallas tile set comfortably
inside VMEM regardless of how many points are being scored.

The block is computed through the same ``StatsBackend`` registry the fit
path uses (``repro.core.engine``): ``"pallas"`` is the tiled MXU kernel
(interpret-mode on CPU), ``"jnp"`` the jit'd XLA path, and an out-of-tree
``register_stats_backend`` name works here too.  Backend *resolution* is
the engine's ``resolve_stats_backend`` — one "Pallas only on TPU" auto
rule shared by fit and predict, so the policy cannot drift between the
two surfaces.

Serving hot path: :func:`get_predict_fn` returns a jitted closure cached
on ``(k, d, metric, backend, rows)``.  jax's jit cache keys on function
*identity* plus argument shapes — rebuilding the closure per request
would retrace every call even at identical shapes, so the closure itself
must be memoised.  Query chunks are padded up to power-of-two row
buckets (:func:`bucket_rows`) so a stream of ragged request sizes
touches at most ``log2(chunk)`` compiled variants instead of one per
distinct size; ``repro.serve.MedoidService`` answers every request
through these closures.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_stats_backend, resolve_stats_backend
from repro.kernels import ops

# Metrics implemented by the Pallas pairwise kernel (kernels/pairwise.py).
PALLAS_METRICS = ops.KERNEL_METRICS

DEFAULT_CHUNK = 8192


def resolve_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a predict ``backend`` argument to a registered
    stats-backend name.

    Delegates to ``repro.core.engine.resolve_stats_backend`` — the single
    owner of the auto/TPU selection rule — and only adapts the error
    type: the predict surface historically raises ``ValueError`` for
    unknown names (the engine registry getter raises ``KeyError``).
    """
    try:
        return resolve_stats_backend(backend, metric)
    except KeyError as e:
        raise ValueError(f"unknown predict backend {backend!r}; "
                         f"{e.args[0] if e.args else e}") from None


def bucket_rows(m: int, chunk: int) -> int:
    """Fixed-shape row bucket for an ``m``-row request: the smallest
    power of two >= m, clamped to ``chunk``.  Bounded bucket set ⇒
    bounded retraces."""
    m = min(max(1, m), chunk)
    return min(1 << (m - 1).bit_length(), chunk)


def assign_rows(m: int) -> int:
    """Row bucket for the chunk-free assignment path: the smallest power
    of two >= m, UNclamped — the streaming top-2 backend pass holds one
    row tile resident regardless of m, so there is no chunk ceiling to
    respect; a stream of ragged sizes still touches only ``log2(m)``
    compiled variants."""
    return 1 << (max(1, m) - 1).bit_length()


@functools.lru_cache(maxsize=None)
def get_predict_fn(k: int, d: int, metric: str, backend: str, rows: int):
    """Jitted ``([rows, d], [k, d]) -> (dist [rows, k], labels [rows],
    dmin [rows])`` closure, memoised on its full trace key.

    ``backend`` must be a *resolved* stats-backend name (callers go
    through :func:`resolve_backend` first) so ``"auto"`` and its
    resolution never alias to two cache entries.  Pad rows beyond the
    logical request are computed and discarded by the caller — every
    registered metric is row-independent, so padding cannot perturb the
    live rows.
    """
    be = get_stats_backend(backend)

    def _fn(xc, med):
        dmat = be.pairwise(xc, med, metric=metric)
        labels = jnp.argmin(dmat, axis=1).astype(jnp.int32)
        return dmat, labels, jnp.min(dmat, axis=1)

    return jax.jit(_fn)


def _run_chunks(x, medoid_points, metric: str, bname: str, chunk: int):
    """Yield ``(lo, m_c, dmat, labels, dmin)`` per padded query chunk."""
    k, d = int(medoid_points.shape[0]), int(medoid_points.shape[1])
    x = np.asarray(x, np.float32)
    m = x.shape[0]
    lo = 0
    while lo < m:
        m_c = min(chunk, m - lo)
        rows = bucket_rows(m_c, chunk)
        fn = get_predict_fn(k, d, metric, bname, rows)
        if m_c == rows:
            xc = x[lo:lo + m_c]
        else:
            xc = np.zeros((rows, d), np.float32)
            xc[:m_c] = x[lo:lo + m_c]
        dmat, labels, dmin = fn(jnp.asarray(xc), medoid_points)
        yield lo, m_c, dmat, labels, dmin
        lo += m_c


def medoid_distances(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                     *, backend: Optional[str] = None,
                     chunk: int = DEFAULT_CHUNK) -> np.ndarray:
    """``[m, d]`` queries × ``[k, d]`` fitted medoids → ``[m, k]`` float32.

    Chunked over the query axis; each chunk is one dispatch through the
    cached jitted closure for its ``(k, d, metric, backend, rows)`` key.
    """
    bname = resolve_backend(backend, metric)
    chunk = max(1, int(chunk))
    out = np.empty((x.shape[0], medoid_points.shape[0]), np.float32)
    for lo, m_c, dmat, _, _ in _run_chunks(x, medoid_points, metric,
                                           bname, chunk):
        out[lo:lo + m_c] = np.asarray(dmat, np.float32)[:m_c]
    return out


@functools.lru_cache(maxsize=None)
def get_assign_fn(k: int, d: int, metric: str, backend: str, rows: int):
    """Jitted ``([rows, d], [k, d]) -> (labels [rows], dmin [rows])``
    closure over the backend's streaming top-2 pass, memoised on its full
    trace key (same discipline as :func:`get_predict_fn`).  One dispatch
    covers any request size — the ``[rows, k]`` distance block is reduced
    tile-by-tile inside the backend and never materialised."""
    be = get_stats_backend(backend)

    def _fn(xc, med):
        d1, _, labels = be.top2(xc, med, metric=metric)
        return labels, d1

    return jax.jit(_fn)


# one warning per process, not per request — serving loops call this hot
_chunk_deprecation_warned = False


def assign_medoids(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                   *, backend: Optional[str] = None,
                   chunk: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """``[m, d]`` queries → ``(labels [m] int32, dmin [m] float32)``.

    The serving assignment path: one streaming dispatch through the
    backend's top-2 contract (``StatsBackend.top2``) for the whole
    request — no host-side chunk loop, no ``[m, k]`` block.

    .. deprecated::
        ``chunk`` is ignored (the streaming pass holds a single row tile
        resident at any m) and will be removed; passing it emits a
        ``DeprecationWarning`` once per process.  ``medoid_distances``
        keeps its ``chunk`` — there the ``[m, k]`` block is the product
        and query chunking still bounds residency.
    """
    global _chunk_deprecation_warned
    if chunk is not None and not _chunk_deprecation_warned:
        _chunk_deprecation_warned = True
        warnings.warn(
            "assign_medoids(chunk=...) is deprecated and ignored: the "
            "streaming top-2 pass needs no query chunking. The parameter "
            "will be removed in a future release.",
            DeprecationWarning, stacklevel=2)
    bname = resolve_backend(backend, metric)
    k, d = int(medoid_points.shape[0]), int(medoid_points.shape[1])
    x = np.asarray(x, np.float32)
    m = x.shape[0]
    if m == 0:
        return np.empty((0,), np.int32), np.empty((0,), np.float32)
    rows = assign_rows(m)
    if rows == m:
        xq = x
    else:
        xq = np.zeros((rows, d), np.float32)
        xq[:m] = x
    fn = get_assign_fn(k, d, metric, bname, rows)
    labels, dmin = fn(jnp.asarray(xq), medoid_points)
    return (np.array(np.asarray(labels, np.int32)[:m]),
            np.array(np.asarray(dmin, np.float32)[:m]))
