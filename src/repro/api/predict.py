"""Batched out-of-sample inference against fitted medoids.

New points never touch the solvers: assigning a query point is one
``[m_query, k]`` pairwise-dissimilarity block against the k medoid rows,
chunked over the query axis so the resident block never exceeds
``chunk × max(k, d)`` — on TPU that keeps each Pallas tile set comfortably
inside VMEM regardless of how many points are being scored.

Two backends compute the block:

* ``"pallas"`` — ``repro.kernels.ops.pairwise_distance`` (the tiled MXU
  kernel; interpret-mode on CPU).  Only the kernel-implemented metrics.
* ``"jnp"`` — ``repro.core.distances.pairwise`` (jit'd XLA).  Any
  registered metric, including user callables.

``"auto"`` routes kernel-supported metrics through Pallas on TPU (the
tiling the kernels are written for) and falls back to jnp everywhere
else — CPU interpret-mode is correct but orders of magnitude slower, and
non-TPU lowerings are unvalidated, so neither is ever auto-selected.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import pairwise
from repro.kernels import ops

# Metrics implemented by the Pallas pairwise kernel (kernels/pairwise.py).
PALLAS_METRICS = ops.KERNEL_METRICS

DEFAULT_CHUNK = 8192


def resolve_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a backend argument to {"pallas", "jnp"}."""
    if backend in (None, "auto"):
        # TPU only: the kernels are TPU-tiled and unvalidated under other
        # lowerings; "auto" never gambles the default path on them.
        if metric in PALLAS_METRICS and jax.default_backend() == "tpu":
            return "pallas"
        return "jnp"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown predict backend {backend!r}; "
                         f"expected 'auto', 'pallas', or 'jnp'")
    if backend == "pallas" and metric not in PALLAS_METRICS:
        raise ValueError(f"metric {metric!r} has no Pallas kernel "
                         f"(kernel metrics: {list(PALLAS_METRICS)}); "
                         f"use backend='jnp'")
    return backend


def medoid_distances(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                     *, backend: Optional[str] = None,
                     chunk: int = DEFAULT_CHUNK) -> np.ndarray:
    """``[m, d]`` queries × ``[k, d]`` fitted medoids → ``[m, k]`` float32.

    Chunked over the query axis; each chunk is one kernel/XLA dispatch.
    """
    backend = resolve_backend(backend, metric)
    chunk = max(1, int(chunk))
    m = x.shape[0]
    out = np.empty((m, medoid_points.shape[0]), np.float32)
    for lo in range(0, m, chunk):
        xc = jnp.asarray(x[lo:lo + chunk], jnp.float32)
        if backend == "pallas":
            d = ops.pairwise_distance(xc, medoid_points, metric=metric)
        else:
            d = pairwise(xc, medoid_points, metric=metric)
        out[lo:lo + chunk] = np.asarray(d, np.float32)
    return out
