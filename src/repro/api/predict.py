"""Batched out-of-sample inference against fitted medoids.

New points never touch the solvers: assigning a query point is one
``[m_query, k]`` pairwise-dissimilarity block against the k medoid rows,
chunked over the query axis so the resident block never exceeds
``chunk × max(k, d)`` — on TPU that keeps each Pallas tile set comfortably
inside VMEM regardless of how many points are being scored.

The block is computed through the same ``StatsBackend`` registry the fit
path uses (``repro.core.engine``): ``"pallas"`` is the tiled MXU kernel
(interpret-mode on CPU), ``"jnp"`` the jit'd XLA path, and an out-of-tree
``register_stats_backend`` name works here too.  Backend *resolution* is
the engine's ``resolve_stats_backend`` — one "Pallas only on TPU" auto
rule shared by fit and predict, so the policy cannot drift between the
two surfaces.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_stats_backend, resolve_stats_backend
from repro.kernels import ops

# Metrics implemented by the Pallas pairwise kernel (kernels/pairwise.py).
PALLAS_METRICS = ops.KERNEL_METRICS

DEFAULT_CHUNK = 8192


def resolve_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a predict ``backend`` argument to a registered
    stats-backend name.

    Delegates to ``repro.core.engine.resolve_stats_backend`` — the single
    owner of the auto/TPU selection rule — and only adapts the error
    type: the predict surface historically raises ``ValueError`` for
    unknown names (the engine registry getter raises ``KeyError``).
    """
    try:
        return resolve_stats_backend(backend, metric)
    except KeyError as e:
        raise ValueError(f"unknown predict backend {backend!r}; "
                         f"{e.args[0] if e.args else e}") from None


def medoid_distances(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                     *, backend: Optional[str] = None,
                     chunk: int = DEFAULT_CHUNK) -> np.ndarray:
    """``[m, d]`` queries × ``[k, d]`` fitted medoids → ``[m, k]`` float32.

    Chunked over the query axis; each chunk is one dispatch through the
    resolved stats backend's pairwise path.
    """
    be = get_stats_backend(resolve_backend(backend, metric))
    chunk = max(1, int(chunk))
    m = x.shape[0]
    out = np.empty((m, medoid_points.shape[0]), np.float32)
    for lo in range(0, m, chunk):
        xc = jnp.asarray(x[lo:lo + chunk], jnp.float32)
        out[lo:lo + chunk] = np.asarray(
            be.pairwise(xc, medoid_points, metric=metric), np.float32)
    return out
