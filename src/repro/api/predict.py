"""Batched out-of-sample inference against fitted medoids.

New points never touch the solvers: assigning a query point is one
``[m_query, k]`` pairwise-dissimilarity block against the k medoid rows,
chunked over the query axis so the resident block never exceeds
``chunk × max(k, d)`` — on TPU that keeps each Pallas tile set comfortably
inside VMEM regardless of how many points are being scored.

The block is computed through the same ``StatsBackend`` registry the fit
path uses (``repro.core.engine``): ``"pallas"`` is the tiled MXU kernel
(interpret-mode on CPU), ``"jnp"`` the jit'd XLA path, and an out-of-tree
``register_stats_backend`` name works here too.  Backend *resolution* is
the engine's ``resolve_stats_backend`` — one "Pallas only on TPU" auto
rule shared by fit and predict, so the policy cannot drift between the
two surfaces.

Serving hot path: :func:`get_predict_fn` returns a jitted closure cached
on ``(k, d, metric, backend, rows)``.  jax's jit cache keys on function
*identity* plus argument shapes — rebuilding the closure per request
would retrace every call even at identical shapes, so the closure itself
must be memoised.  Query chunks are padded up to power-of-two row
buckets (:func:`bucket_rows`) so a stream of ragged request sizes
touches at most ``log2(chunk)`` compiled variants instead of one per
distinct size; ``repro.serve.MedoidService`` answers every request
through these closures.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import get_stats_backend, resolve_stats_backend
from repro.kernels import ops

# Metrics implemented by the Pallas pairwise kernel (kernels/pairwise.py).
PALLAS_METRICS = ops.KERNEL_METRICS

DEFAULT_CHUNK = 8192


def resolve_backend(backend: Optional[str], metric: str) -> str:
    """Normalise a predict ``backend`` argument to a registered
    stats-backend name.

    Delegates to ``repro.core.engine.resolve_stats_backend`` — the single
    owner of the auto/TPU selection rule — and only adapts the error
    type: the predict surface historically raises ``ValueError`` for
    unknown names (the engine registry getter raises ``KeyError``).
    """
    try:
        return resolve_stats_backend(backend, metric)
    except KeyError as e:
        raise ValueError(f"unknown predict backend {backend!r}; "
                         f"{e.args[0] if e.args else e}") from None


def bucket_rows(m: int, chunk: int) -> int:
    """Fixed-shape row bucket for an ``m``-row request: the smallest
    power of two >= m, clamped to ``chunk``.  Bounded bucket set ⇒
    bounded retraces."""
    m = min(max(1, m), chunk)
    return min(1 << (m - 1).bit_length(), chunk)


@functools.lru_cache(maxsize=None)
def get_predict_fn(k: int, d: int, metric: str, backend: str, rows: int):
    """Jitted ``([rows, d], [k, d]) -> (dist [rows, k], labels [rows],
    dmin [rows])`` closure, memoised on its full trace key.

    ``backend`` must be a *resolved* stats-backend name (callers go
    through :func:`resolve_backend` first) so ``"auto"`` and its
    resolution never alias to two cache entries.  Pad rows beyond the
    logical request are computed and discarded by the caller — every
    registered metric is row-independent, so padding cannot perturb the
    live rows.
    """
    be = get_stats_backend(backend)

    def _fn(xc, med):
        dmat = be.pairwise(xc, med, metric=metric)
        labels = jnp.argmin(dmat, axis=1).astype(jnp.int32)
        return dmat, labels, jnp.min(dmat, axis=1)

    return jax.jit(_fn)


def _run_chunks(x, medoid_points, metric: str, bname: str, chunk: int):
    """Yield ``(lo, m_c, dmat, labels, dmin)`` per padded query chunk."""
    k, d = int(medoid_points.shape[0]), int(medoid_points.shape[1])
    x = np.asarray(x, np.float32)
    m = x.shape[0]
    lo = 0
    while lo < m:
        m_c = min(chunk, m - lo)
        rows = bucket_rows(m_c, chunk)
        fn = get_predict_fn(k, d, metric, bname, rows)
        if m_c == rows:
            xc = x[lo:lo + m_c]
        else:
            xc = np.zeros((rows, d), np.float32)
            xc[:m_c] = x[lo:lo + m_c]
        dmat, labels, dmin = fn(jnp.asarray(xc), medoid_points)
        yield lo, m_c, dmat, labels, dmin
        lo += m_c


def medoid_distances(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                     *, backend: Optional[str] = None,
                     chunk: int = DEFAULT_CHUNK) -> np.ndarray:
    """``[m, d]`` queries × ``[k, d]`` fitted medoids → ``[m, k]`` float32.

    Chunked over the query axis; each chunk is one dispatch through the
    cached jitted closure for its ``(k, d, metric, backend, rows)`` key.
    """
    bname = resolve_backend(backend, metric)
    chunk = max(1, int(chunk))
    out = np.empty((x.shape[0], medoid_points.shape[0]), np.float32)
    for lo, m_c, dmat, _, _ in _run_chunks(x, medoid_points, metric,
                                           bname, chunk):
        out[lo:lo + m_c] = np.asarray(dmat, np.float32)[:m_c]
    return out


def assign_medoids(x: np.ndarray, medoid_points: jnp.ndarray, metric: str,
                   *, backend: Optional[str] = None,
                   chunk: int = DEFAULT_CHUNK
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """``[m, d]`` queries → ``(labels [m] int32, dmin [m] float32)``.

    The serving assignment path: label + nearest-medoid distance come out
    of the same dispatch as the distance block, so the drift monitor's
    loss samples are free once a request has been answered.
    """
    bname = resolve_backend(backend, metric)
    chunk = max(1, int(chunk))
    m = x.shape[0]
    labels = np.empty((m,), np.int32)
    dmin = np.empty((m,), np.float32)
    for lo, m_c, _, lab_c, dmin_c in _run_chunks(x, medoid_points, metric,
                                                 bname, chunk):
        labels[lo:lo + m_c] = np.asarray(lab_c, np.int32)[:m_c]
        dmin[lo:lo + m_c] = np.asarray(dmin_c, np.float32)[:m_c]
    return labels, dmin
