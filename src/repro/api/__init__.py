# repro.api — the estimator facade: one sklearn-style KMedoids fronting
# every registered k-medoids solver, with out-of-sample inference and the
# unified FitReport ledger.  The stats-backend registry (repro.core.engine)
# is re-exported here so backend selection/extension lives on the same
# surface as solver and metric registration.
from repro.core.distances import (attach_index, available_metrics,
                                  register_metric, resolve_metric)
from repro.core.engine import (available_stats_backends, get_stats_backend,
                               register_stats_backend, resolve_stats_backend)
from repro.core.report import BatchFitReport, FitReport

from .estimator import KMedoids
from .predict import (PALLAS_METRICS, assign_medoids, get_predict_fn,
                      medoid_distances, resolve_backend)
from .registry import (available_batch_solvers, available_solvers,
                       default_params, get_batch_solver, get_solver,
                       register_solver, solver_accepts_backend)

__all__ = [
    "KMedoids", "FitReport", "BatchFitReport", "register_solver",
    "get_solver", "get_batch_solver",
    "available_solvers", "available_batch_solvers",
    "default_params", "solver_accepts_backend",
    "register_metric", "available_metrics",
    "resolve_metric", "attach_index", "medoid_distances", "assign_medoids",
    "get_predict_fn", "resolve_backend", "PALLAS_METRICS",
    "register_stats_backend", "get_stats_backend",
    "available_stats_backends", "resolve_stats_backend",
]
