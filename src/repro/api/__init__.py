# repro.api — the estimator facade: one sklearn-style KMedoids fronting
# every registered k-medoids solver, with out-of-sample inference and the
# unified FitReport ledger.
from repro.core.distances import (attach_index, available_metrics,
                                  register_metric, resolve_metric)
from repro.core.report import FitReport

from .estimator import KMedoids
from .predict import PALLAS_METRICS, medoid_distances, resolve_backend
from .registry import (available_solvers, default_params, get_solver,
                       register_solver)

__all__ = [
    "KMedoids", "FitReport", "register_solver", "get_solver",
    "available_solvers", "default_params", "register_metric",
    "available_metrics",
    "resolve_metric", "attach_index", "medoid_distances", "resolve_backend",
    "PALLAS_METRICS",
]
