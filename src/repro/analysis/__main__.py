"""``python -m repro.analysis`` — the tracecheck CLI.

Examples::

    python -m repro.analysis src/repro
    python -m repro.analysis src --format json --output tracecheck.json
    python -m repro.analysis --imports --check-quarantine
    python -m repro.analysis --list-rules

Exit codes: 0 clean, 1 findings (or quarantine drift), 2 usage error.
The CLI is stdlib-only — it never imports jax, so it is safe to run in
lint-stage CI images.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import config as config_mod
from . import engine
from .rules import ALL_RULES, RULE_DOCS


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracecheck: AST contract linter for the repro engine")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/directories to scan (default: src)")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--output", metavar="FILE",
                        help="also write the JSON report to FILE")
    parser.add_argument("--rules", metavar="CSV",
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--imports", action="store_true",
                        help="print the import-graph/dead-module report")
    parser.add_argument("--check-quarantine", action="store_true",
                        help="with --imports: fail on undocumented dormant "
                             "modules or stale quarantine entries")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULE_DOCS):
            print(f"{rid}: {RULE_DOCS[rid]}")
        return 0

    cfg = config_mod.default_config()
    rc = 0

    if args.imports:
        from . import imports as imports_mod
        repo_root = os.getcwd()
        report = imports_mod.build_report(repo_root, cfg)
        print(imports_mod.format_report(report, cfg))
        if args.check_quarantine:
            undocumented, stale = imports_mod.check_quarantine(report, cfg)
            if undocumented or stale:
                rc = 1
        if not args.paths:
            return rc

    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    rules = ALL_RULES
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - {r.rule_id for r in ALL_RULES}
        if unknown:
            print(f"error: unknown rules: {sorted(unknown)}",
                  file=sys.stderr)
            return 2
        rules = tuple(r for r in ALL_RULES if r.rule_id in wanted)

    report = engine.run(paths, cfg, rules=rules)
    if args.output:
        engine.dump_json(report, args.output)
    if args.format == "json":
        json.dump(engine.report_to_json(report), sys.stdout, indent=2,
                  sort_keys=True)
        print()
    else:
        print(engine.format_human(report))
    return 1 if report.findings else rc


if __name__ == "__main__":
    sys.exit(main())
