"""tracecheck import-graph report — live / test-only / dead modules.

Builds the intra-``repro`` import graph by AST (module-level *and*
function-level imports, absolute and relative), classifies every module
under ``src/repro`` as

* ``live``      — reachable from the product roots
  (``Config.product_roots``: the ``repro.api`` facade, ``repro.serve``,
  and this analysis package),
* ``test-only`` — unreachable from the product surface but imported
  (transitively) by ``tests/``, ``benchmarks/`` or ``examples/``,
* ``dead``      — imported by nothing at all.

``check_quarantine`` turns the report into a blocking contract: every
non-live module must appear in ``Config.quarantine`` (the documented
dormant-LM-scaffolding list, docs/design.md #9), and nothing listed
there may silently go live — the list stays exact in both directions.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Set, Tuple

from .config import Config

__all__ = ["build_report", "check_quarantine", "format_report"]


def _module_name(path: str, src_root: str) -> str:
    rel = os.path.relpath(path, src_root).replace(os.sep, "/")
    rel = rel[:-3] if rel.endswith(".py") else rel
    if rel.endswith("/__init__"):
        rel = rel[: -len("/__init__")]
    return rel.replace("/", ".")


def _discover(src_root: str) -> Dict[str, str]:
    mods: Dict[str, str] = {}
    for root, dirs, files in os.walk(src_root):
        dirs[:] = sorted(d for d in dirs if d != "__pycache__")
        for name in sorted(files):
            if name.endswith(".py"):
                p = os.path.join(root, name)
                mods[_module_name(p, src_root)] = p
    return mods


def _imports_of(path: str, modname: str, known: Set[str]) -> Set[str]:
    """``repro.*`` modules this file imports (module granularity)."""
    with open(path, encoding="utf-8") as fh:
        try:
            tree = ast.parse(fh.read(), filename=path)
        except SyntaxError:
            return set()
    out: Set[str] = set()

    def add(candidate: str) -> None:
        # Trim attribute tails until we hit a known module.
        parts = candidate.split(".")
        while parts:
            cand = ".".join(parts)
            if cand in known:
                out.add(cand)
                return
            parts.pop()

    is_pkg = path.endswith("__init__.py")
    pkg_parts = modname.split(".") if is_pkg else modname.split(".")[:-1]

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0:
                base = node.module or ""
                if not (base == "repro" or base.startswith("repro.")):
                    continue
            else:
                up = node.level - 1
                if up > len(pkg_parts):
                    continue
                base_parts = pkg_parts[: len(pkg_parts) - up] if up else \
                    list(pkg_parts)
                base = ".".join(base_parts + (
                    [node.module] if node.module else []))
            if base:
                add(base)
            for a in node.names:
                if a.name != "*" and base:
                    add(f"{base}.{a.name}")
    out.discard(modname)
    return out


# Several tests drive multi-process scenarios through subprocess scripts
# embedded as string literals; their imports are invisible to the AST, so
# the external scan also regex-greps raw text for repro imports.
_TEXT_IMPORT_RE = re.compile(
    r"(?:from\s+(repro(?:\.\w+)*)\s+import)|(?:\bimport\s+(repro(?:\.\w+)+))")


def _external_roots(repo_root: str, known: Set[str],
                    scan_dirs: Iterable[str]) -> Dict[str, Set[str]]:
    """repro modules imported by tests/benchmarks/examples → importers."""
    roots: Dict[str, Set[str]] = {}
    for d in scan_dirs:
        base = os.path.join(repo_root, d)
        if not os.path.isdir(base):
            continue
        for root, dirs, files in os.walk(base):
            dirs[:] = sorted(x for x in dirs if x != "__pycache__")
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                p = os.path.join(root, name)
                rel = os.path.relpath(p, repo_root).replace(os.sep, "/")
                mods = _imports_of(p, f"<{rel}>", known)
                with open(p, encoding="utf-8") as fh:
                    for m in _TEXT_IMPORT_RE.finditer(fh.read()):
                        cand = m.group(1) or m.group(2)
                        parts = cand.split(".")
                        while parts:
                            if ".".join(parts) in known:
                                mods.add(".".join(parts))
                                break
                            parts.pop()
                for mod in mods:
                    roots.setdefault(mod, set()).add(rel)
    return roots


def _closure(seeds: Iterable[str], graph: Dict[str, Set[str]]) -> Set[str]:
    seen: Set[str] = set()
    todo = list(seeds)
    while todo:
        m = todo.pop()
        if m in seen:
            continue
        seen.add(m)
        todo.extend(graph.get(m, ()))
        # Importing a submodule executes the package __init__ too.
        if "." in m:
            todo.append(m.rsplit(".", 1)[0])
    return seen


def build_report(repo_root: str, config: Config,
                 src: str = "src") -> Dict[str, dict]:
    src_root = os.path.join(repo_root, src)
    mods = _discover(src_root)
    known = set(mods)
    graph = {m: _imports_of(p, m, known) for m, p in mods.items()}

    # Exact-module seeds: importing a package root executes its __init__,
    # whose own imports are edges in the graph — so submodules go live only
    # if the package (or another live module) actually pulls them in.
    product_seeds = [r for r in config.product_roots if r in known]
    live = _closure(product_seeds, graph)

    ext = _external_roots(repo_root, known,
                          ("tests", "benchmarks", "examples"))
    test_reach = _closure(ext.keys(), graph)

    importers: Dict[str, Set[str]] = {m: set() for m in known}
    for m, deps in graph.items():
        for d in deps:
            importers[d].add(m)
    for m, files in ext.items():
        importers[m].update(files)

    report: Dict[str, dict] = {}
    for m in sorted(known):
        if m in live:
            status = "live"
        elif m in test_reach:
            status = "test-only"
        else:
            status = "dead"
        report[m] = {
            "status": status,
            "path": os.path.relpath(mods[m], repo_root).replace(os.sep, "/"),
            "imported_by": sorted(importers[m]),
        }
    return report


def check_quarantine(report: Dict[str, dict],
                     config: Config) -> Tuple[List[str], List[str]]:
    """→ (undocumented dormant modules, stale quarantine entries)."""
    quarantined = set(config.quarantine)
    dormant = {m for m, info in report.items()
               if info["status"] != "live"}
    undocumented = sorted(dormant - quarantined)
    stale = sorted(q for q in quarantined
                   if q in report and report[q]["status"] == "live")
    return undocumented, stale


def format_report(report: Dict[str, dict], config: Config) -> str:
    lines = []
    counts = {"live": 0, "test-only": 0, "dead": 0}
    for m, info in report.items():
        counts[info["status"]] += 1
        if info["status"] != "live":
            q = " (quarantined)" if m in config.quarantine else ""
            by = ", ".join(info["imported_by"][:3]) or "nothing"
            lines.append(f"  {info['status']:9s} {m}{q}  <- {by}")
    undocumented, stale = check_quarantine(report, config)
    head = (f"import graph: {counts['live']} live, "
            f"{counts['test-only']} test-only, {counts['dead']} dead")
    lines.insert(0, head)
    if undocumented:
        lines.append("UNDOCUMENTED dormant modules (add to quarantine or "
                     "delete): " + ", ".join(undocumented))
    if stale:
        lines.append("STALE quarantine entries (module is live): "
                     + ", ".join(stale))
    return "\n".join(lines)
