"""tracecheck engine — AST visitor framework + per-module reachability.

The engine owns everything rule modules share:

* :class:`ModuleContext` — one parsed file: import-alias resolution
  (``jnp.asarray`` → ``jax.numpy.asarray``), a qualified-name function
  table, a per-module call/reference graph, and the **jit-reachability
  closure**.  Roots are functions decorated with (or wrapped by)
  ``jax.jit``-family transforms and closures handed to trace-taking
  callables (``lax.fori_loop``/``while_loop``/``scan``/``cond``/
  ``switch``/``map``, ``vmap``/``pmap``/``shard_map``, plus
  config-listed extras like ``adaptive_search``); reachability
  propagates along call/reference edges and into functions *defined
  inside* reachable functions (closure bodies trace with their parent).
* Suppressions — ``# tracecheck: ignore[TRC00x] -- reason`` on the
  finding's line or alone on the preceding line.  The justification is
  mandatory: a bare ``ignore[...]`` suppresses its target but raises
  TRC000.
* :class:`Finding`, the runner (:func:`run`), and JSON/human reports.

Host-orchestration code (``fit`` drivers, result assembly) is *not*
jit-reachable by construction, so host reads there never fire TRC001 —
the rules only police code that executes under a trace.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

from .config import Config, path_in_scope

__all__ = [
    "Finding", "FuncInfo", "ModuleContext", "Report",
    "analyze_file", "run", "format_human", "report_to_json",
]

SUPPRESS_RE = re.compile(
    r"#\s*tracecheck:\s*ignore\[([A-Za-z0-9_,\s]+)\]\s*(?:--\s*(\S.*))?")

# Callables whose function-valued arguments execute under a trace.
TRACE_TAKERS = frozenset({
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.custom_jvp", "jax.custom_vjp",
    "jax.lax.while_loop", "jax.lax.fori_loop", "jax.lax.scan",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.lax.custom_root",
    "jax.experimental.shard_map.shard_map",
})

# Decorators that make the decorated function a trace root.
JIT_DECORATORS = frozenset({
    "jax.jit", "jax.vmap", "jax.pmap",
    "jax.experimental.shard_map.shard_map",
})

_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    function: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def human(self) -> str:
        where = f" [{self.function}]" if self.function else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}{where} {self.message}")


@dataclasses.dataclass
class FuncInfo:
    qualname: str
    node: ast.AST                     # FunctionDef / AsyncFunctionDef / Lambda
    parent: Optional[str] = None      # qualname of enclosing *function*
    cls: Optional[str] = None         # name of enclosing class, if a method
    reach_reason: str = ""            # why jit-reachable ("" = not reachable)


class _FuncCollector(ast.NodeVisitor):
    """Builds the function table with dotted qualified names."""

    def __init__(self) -> None:
        self.funcs: Dict[str, FuncInfo] = {}
        self.by_node: Dict[int, FuncInfo] = {}
        self._scope: List[str] = []          # qualname parts
        self._func_stack: List[str] = []     # enclosing function qualnames
        self._class_stack: List[str] = []

    def _qual(self, name: str) -> str:
        return ".".join(self._scope + [name])

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scope.append(node.name)
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()
        self._scope.pop()

    def _visit_func(self, node) -> None:
        qual = self._qual(node.name)
        info = FuncInfo(
            qualname=qual,
            node=node,
            parent=self._func_stack[-1] if self._func_stack else None,
            cls=self._class_stack[-1] if self._class_stack else None,
        )
        # First definition wins for name collisions (rare; over-approx).
        self.funcs.setdefault(qual, info)
        self.by_node[id(node)] = info
        self._scope.append(node.name)
        self._func_stack.append(qual)
        self.generic_visit(node)
        self._func_stack.pop()
        self._scope.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


class ModuleContext:
    """One parsed source file plus everything the rules need to see."""

    def __init__(self, path: str, source: str, config: Config) -> None:
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.config = config
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self.suppressions, self.bare_suppressions = self._parse_suppressions()
        self.aliases = self._collect_aliases()
        collector = _FuncCollector()
        collector.visit(self.tree)
        self.functions: Dict[str, FuncInfo] = collector.funcs
        self._by_node = collector.by_node
        self._lambda_roots: List[FuncInfo] = []
        self._simple_names: Dict[str, List[str]] = {}
        for qual in self.functions:
            self._simple_names.setdefault(qual.rsplit(".", 1)[-1],
                                          []).append(qual)
        self._edges = self._call_graph()
        self._reachable = self._reachability_closure()

    # ---------------------------------------------------------- aliases

    def _collect_aliases(self) -> Dict[str, str]:
        amap: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        amap[a.asname] = a.name
                    else:
                        first = a.name.split(".", 1)[0]
                        amap[first] = first
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                mod = node.module or ""
                for a in node.names:
                    if a.name == "*":
                        continue
                    amap[a.asname or a.name] = (
                        f"{mod}.{a.name}" if mod else a.name)
        return amap

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain with aliases applied."""
        if isinstance(node, ast.Name):
            return self.aliases.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            return None if base is None else f"{base}.{node.attr}"
        return None

    # ------------------------------------------------------ suppressions

    def _parse_suppressions(self) -> Tuple[Dict[int, Set[str]], List[int]]:
        sup: Dict[int, Set[str]] = {}
        bare: List[int] = []
        lines = self.source.splitlines()
        for i, line in enumerate(lines, 1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            code = line.split("#", 1)[0]
            if code.strip():
                target = i
            else:
                # Standalone comment: applies to the next code line, so a
                # multi-line justification block stays one suppression.
                target = i + 1
                for j in range(i, len(lines)):
                    stripped = lines[j].strip()
                    if stripped and not stripped.startswith("#"):
                        target = j + 1
                        break
            sup.setdefault(target, set()).update(rules)
            if not m.group(2):
                bare.append(i)
        return sup, bare

    def suppressed(self, rule: str, line: int) -> bool:
        return rule in self.suppressions.get(line, ())

    # -------------------------------------------------------- call graph

    def _local_targets(self, node: ast.AST) -> List[str]:
        """Local functions a Name/Attribute reference may point at."""
        if isinstance(node, ast.Name):
            if node.id in self.aliases and self.aliases[node.id] != node.id:
                return []  # shadowed by an import
            return list(self._simple_names.get(node.id, ()))
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in ("self", "cls")):
            return list(self._simple_names.get(node.attr, ()))
        return []

    def _call_graph(self) -> Dict[str, Set[str]]:
        edges: Dict[str, Set[str]] = {q: set() for q in self.functions}
        for info in self.functions.values():
            for node in self.walk_own(info.node):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    for tgt in self._local_targets(node):
                        if tgt != info.qualname:
                            edges[info.qualname].add(tgt)
        return edges

    # ------------------------------------------------------ reachability

    def _is_banned(self, qual: str) -> bool:
        hb = self.config.host_boundary
        return any(qual == b or qual.endswith("." + b) for b in hb)

    def _decorator_roots(self) -> Iterator[Tuple[str, str]]:
        for info in self.functions.values():
            node = info.node
            if not isinstance(node, _FUNC_DEFS):
                continue
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                r = self.resolve(target)
                if r in JIT_DECORATORS:
                    yield info.qualname, f"decorated @{r}"
                elif r in ("functools.partial", "partial") and isinstance(
                        dec, ast.Call):
                    if dec.args and self.resolve(
                            dec.args[0]) in JIT_DECORATORS:
                        yield (info.qualname,
                               f"decorated @partial({self.resolve(dec.args[0])})")

    def _callsite_roots(self) -> Iterator[Tuple[str, str]]:
        extra = set(self.config.extra_trace_takers)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            r = self.resolve(node.func)
            simple = r.rsplit(".", 1)[-1] if r else None
            if r not in TRACE_TAKERS and simple not in extra:
                continue
            taker = r or simple
            args = list(node.args) + [kw.value for kw in node.keywords]
            for a in args:
                if isinstance(a, ast.Lambda):
                    info = FuncInfo(
                        qualname=f"<lambda:{a.lineno}>", node=a,
                        reach_reason=f"lambda passed to {taker}")
                    self._lambda_roots.append(info)
                    continue
                for tgt in self._local_targets(a):
                    yield tgt, f"passed to {taker}"
                if isinstance(a, ast.Call):
                    # functools.partial(fn, ...) handed to a trace taker
                    pr = self.resolve(a.func)
                    if pr in ("functools.partial", "partial"):
                        for pa in a.args:
                            for tgt in self._local_targets(pa):
                                yield tgt, f"partial passed to {taker}"

    def _assignment_roots(self) -> Iterator[Tuple[str, str]]:
        # X = jax.jit(fn, ...)  /  X = functools.partial(jax.jit, ...)(fn)
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            value = getattr(node, "value", None)
            if not isinstance(value, ast.Call):
                continue
            r = self.resolve(value.func)
            if r in JIT_DECORATORS:
                for a in value.args:
                    for tgt in self._local_targets(a):
                        yield tgt, f"wrapped by {r} assignment"

    def _reachability_closure(self) -> Dict[str, str]:
        reach: Dict[str, str] = {}

        def add(qual: str, reason: str) -> None:
            if qual in self.functions and qual not in reach:
                if not self._is_banned(qual):
                    reach[qual] = reason

        for qual, reason in self._decorator_roots():
            add(qual, reason)
        for qual, reason in self._assignment_roots():
            add(qual, reason)
        for qual, reason in self._callsite_roots():
            add(qual, reason)
        if path_in_scope(self.path, self.config.all_roots_paths):
            for qual, info in self.functions.items():
                if info.parent is None and info.cls is None:
                    add(qual, "kernel-module public surface")

        changed = True
        while changed:
            changed = False
            for qual in list(reach):
                for succ in self._edges.get(qual, ()):
                    if succ not in reach:
                        add(succ, f"called from {qual}")
                        changed = succ in reach or changed
            for qual, info in self.functions.items():
                if qual in reach or info.parent is None:
                    continue
                if info.parent in reach:
                    add(qual, f"defined inside {info.parent}")
                    changed = qual in reach or changed

        for info in self.functions.values():
            info.reach_reason = reach.get(info.qualname, "")
        return reach

    # ---------------------------------------------------------- walking

    @staticmethod
    def walk_own(func_node: ast.AST) -> Iterator[ast.AST]:
        """Walk a function body without descending into nested defs.

        Lambdas ARE descended into: a lambda inside a traced function
        traces with it, and lambdas have no table entry of their own
        unless passed straight to a trace taker.
        """
        body = getattr(func_node, "body", None)
        todo = list(body) if isinstance(body, list) else [body]
        while todo:
            n = todo.pop()
            if n is None or isinstance(n, _FUNC_DEFS):
                continue
            yield n
            todo.extend(ast.iter_child_nodes(n))

    def reachable_functions(self) -> Iterator[FuncInfo]:
        for info in self.functions.values():
            if info.reach_reason:
                yield info
        for info in self._lambda_roots:
            yield info

    def walk_scoped(self) -> Iterator[Tuple[ast.AST, str]]:
        """Yield every node with its enclosing function qualname ("" =
        module level)."""

        def rec(node: ast.AST, scope: str) -> Iterator[Tuple[ast.AST, str]]:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, _FUNC_DEFS):
                    info = self._by_node.get(id(child))
                    inner = info.qualname if info else child.name
                    yield child, scope
                    yield from rec(child, inner)
                else:
                    yield child, scope
                    yield from rec(child, scope)

        yield from rec(self.tree, "")

    def finding(self, rule: str, node: ast.AST, message: str,
                function: str = "") -> Finding:
        return Finding(rule=rule, path=self.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message, function=function)


# ------------------------------------------------------------------ runner

@dataclasses.dataclass
class Report:
    findings: List[Finding]
    files_scanned: int
    suppressed: int

    @property
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return dict(sorted(out.items()))


def _iter_py_files(paths: Iterable[str],
                   exclude: Tuple[str, ...]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(
                d for d in dirs
                if not path_in_scope(
                    os.path.join(root, d).replace(os.sep, "/") + "/",
                    exclude))
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def analyze_file(path: str, config: Config,
                 rules=None) -> Tuple[List[Finding], int]:
    """Run the rule pack on one file → (findings, n_suppressed)."""
    from . import rules as rulepack
    if rules is None:
        rules = rulepack.ALL_RULES
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    try:
        ctx = ModuleContext(path, source, config)
    except SyntaxError as exc:
        return [Finding("TRC-PARSE", path.replace(os.sep, "/"),
                        exc.lineno or 0, exc.offset or 0,
                        f"could not parse: {exc.msg}")], 0

    findings: List[Finding] = []
    suppressed = 0
    for rule in rules:
        scope = config.rule_scope(rule.rule_id)
        if scope and not path_in_scope(ctx.path, scope):
            continue
        for f in rule.check(ctx, config):
            if ctx.suppressed(f.rule, f.line):
                suppressed += 1
            else:
                findings.append(f)
    # TRC000: suppression comments without a `-- reason` justification.
    for line in ctx.bare_suppressions:
        findings.append(Finding(
            "TRC000", ctx.path, line, 0,
            "suppression without justification — use "
            "`# tracecheck: ignore[RULE] -- <why this is safe>`"))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed


def run(paths: Iterable[str], config: Config, rules=None) -> Report:
    findings: List[Finding] = []
    suppressed = 0
    n_files = 0
    for path in _iter_py_files(paths, config.exclude):
        n_files += 1
        fs, sup = analyze_file(path, config, rules=rules)
        findings.extend(fs)
        suppressed += sup
    return Report(findings=findings, files_scanned=n_files,
                  suppressed=suppressed)


# ----------------------------------------------------------------- output

def report_to_json(report: Report) -> dict:
    return {
        "tool": "tracecheck",
        "version": 1,
        "files_scanned": report.files_scanned,
        "suppressed": report.suppressed,
        "counts": report.counts,
        "findings": [f.to_json() for f in report.findings],
    }


def format_human(report: Report) -> str:
    lines = [f.human() for f in report.findings]
    tail = (f"{len(report.findings)} finding(s) in "
            f"{report.files_scanned} file(s), "
            f"{report.suppressed} suppressed")
    if report.findings:
        per_rule = ", ".join(f"{k}={v}" for k, v in report.counts.items())
        tail += f" [{per_rule}]"
    lines.append(tail)
    return "\n".join(lines)


def dump_json(report: Report, path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(report_to_json(report), fh, indent=2, sort_keys=True)
        fh.write("\n")
