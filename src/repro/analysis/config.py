"""tracecheck configuration — the repo contract, as data.

One :class:`Config` instance describes which rule applies where
(per-directory scopes), which functions are sanctioned RNG-chain heads,
which modules are deliberately quarantined LM scaffolding, and the small
set of repo-specific analysis hints (extra trace-taking callables, files
whose whole public surface is jit-reachable).  ``default_config()``
encodes the shipped tree's contracts; tests build narrower configs for
the fixture corpus, and out-of-tree users can construct their own.

The scope patterns are directory/file suffixes matched against posix
paths: ``"core/"`` matches any file under a ``core`` directory component
(so the fixture corpus at ``tests/fixtures/tracecheck/bad/core/`` lands
in the same scopes as ``src/repro/core/``), ``"core/banditpam.py"``
matches that file wherever its tree is rooted, and ``"*"`` matches
everything.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = ["Config", "default_config", "path_in_scope", "LM_QUARANTINE"]


def path_in_scope(path: str, patterns: Tuple[str, ...]) -> bool:
    """True if ``path`` (posix-ish) matches any scope pattern."""
    p = "/" + path.replace("\\", "/").lstrip("/")
    for pat in patterns:
        if pat == "*":
            return True
        if pat.endswith("/"):
            if ("/" + pat) in (p + "/"):
                return True
        elif p.endswith("/" + pat):
            return True
    return False


# Modules that are deliberately retained although the clustering product
# surface never imports them: the LM training/serving scaffolding the
# k-medoids engine grew alongside (docs/design.md Part B).  They are
# reachable only from their dedicated tests/examples ("test-only" in the
# import report).  Anything ELSE that turns up dormant is an error — the
# quarantine list is exhaustive by design, mirroring the PR-7
# ``serve/lm.py`` precedent of explicit, documented quarantine.
LM_QUARANTINE: Tuple[str, ...] = (
    "repro.configs",
    "repro.configs.arctic_480b",
    "repro.configs.base",
    "repro.configs.falcon_mamba_7b",
    "repro.configs.gemma3_12b",
    "repro.configs.granite_8b",
    "repro.configs.llama4_scout_17b",
    "repro.configs.mistral_nemo_12b",
    "repro.configs.musicgen_large",
    "repro.configs.phi3_vision_4_2b",
    "repro.configs.qwen3_1_7b",
    "repro.configs.zamba2_2_7b",
    "repro.distributed",
    "repro.distributed.compression",
    "repro.distributed.pipeline",
    "repro.distributed.sharding",
    "repro.launch.dryrun",
    "repro.launch.mesh",
    "repro.launch.serve",
    "repro.launch.specs",
    "repro.launch.train",
    "repro.models",
    "repro.models.layers",
    "repro.models.model",
    "repro.models.moe",
    "repro.models.ssm",
    "repro.runtime.elastic",
    "repro.runtime.fault",
    "repro.serve.lm",
    "repro.train",
    "repro.train.compressed",
    "repro.train.data",
    "repro.train.optimizer",
    "repro.train.train_step",
)


@dataclasses.dataclass
class Config:
    """Rule scopes + repo-specific analysis hints (see module docstring)."""

    # rule id -> path patterns the rule runs on
    scopes: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=dict)
    # path patterns skipped entirely
    exclude: Tuple[str, ...] = ("__pycache__/",)

    # TRC003: qualified function names allowed to construct raw PRNGKeys
    # (the heads of the documented (seed, phase, selection, round, shard)
    # fold_in chains — everything else must derive keys by fold_in/split).
    sanctioned_key_constructors: Tuple[str, ...] = ()

    # Callables (simple names) whose function-valued arguments are traced
    # — beyond the jax.jit/lax.* builtins the engine already knows.
    extra_trace_takers: Tuple[str, ...] = ()

    # Files whose module-level functions are ALL jit-reachable public
    # surface (the Pallas kernel wrappers: called inside jit from other
    # modules, so per-module root detection cannot see their callers).
    all_roots_paths: Tuple[str, ...] = ()
    # ...except these qualified names (host-side deployment hooks).
    host_boundary: Tuple[str, ...] = ()

    # TRC005 sub-scopes (the rule id shares one suppression token).
    trc005_vmap: Tuple[str, ...] = ()
    trc005_setinf: Tuple[str, ...] = ()
    trc005_f32: Tuple[str, ...] = ()

    # Import-graph report: product roots + documented dormant modules.
    product_roots: Tuple[str, ...] = ()
    quarantine: Tuple[str, ...] = ()

    def rule_scope(self, rule_id: str) -> Tuple[str, ...]:
        return self.scopes.get(rule_id, ())


def default_config() -> Config:
    """The shipped repo contract (rule catalogue in docs/design.md #9)."""
    return Config(
        scopes={
            # Host-sync calls on traced values in jit-reachable engine code.
            "TRC001": ("core/", "kernels/"),
            # Python for/while unrolling into a jit trace.
            "TRC002": ("core/", "kernels/"),
            # Raw PRNGKeys outside the sanctioned fold_in chain heads.
            "TRC003": ("core/", "kernels/", "serve/"),
            # Collectives inside StatsBackend implementations (anywhere).
            "TRC004": ("*",),
            # Parity breakers (union of the sub-scopes below).
            "TRC005": ("core/banditpam.py", "core/engine.py", "kernels/",
                       "serve/drift.py", "runtime/checkpoint.py"),
        },
        sanctioned_key_constructors=(
            # single-device driver: the one chain head per fit
            "BanditPAM.fit",
            # batched multi-fit: replicates the fit chain, vmapped
            "_batch_rng_chains.chain",
            # sharded driver: (seed ^ phase_tag) chain head + fit entry
            "_phase_key",
            "DistributedBanditPAM.fit",
            # onebatch solver: one chain head per solve
            "onebatchpam",
            # serving reservoir: one fixed key, draws fold_in(stream idx)
            "Reservoir.__init__",
        ),
        extra_trace_takers=(
            # adaptive_search traces its stats_fn/exact_fn/count_fn args
            "adaptive_search",
            # shard_map closures execute inside jit
            "shard_map", "_shard_map",
        ),
        all_roots_paths=("kernels/",),
        host_boundary=(
            # TPU deployment hook: re-registers metrics, pure host code
            "install",
            # interpret-mode default probe, called at wrapper entry
            "_default_interpret",
        ),
        trc005_vmap=("core/banditpam.py",),
        trc005_setinf=("core/engine.py", "kernels/"),
        trc005_f32=("serve/drift.py", "runtime/checkpoint.py"),
        product_roots=(
            "repro.api", "repro.serve",
            # analysis entry points beyond the package __init__: the CLI
            # and the pytest guard plugin are imported by name, not via
            # the package front.
            "repro.analysis", "repro.analysis.__main__",
            "repro.analysis.guard", "repro.analysis.imports",
            # graphcheck: its own CLI entry, plus the benchmark harness
            # imports the budgets/registry modules directly
            "repro.analysis.graph", "repro.analysis.graph.__main__",
        ),
        quarantine=LM_QUARANTINE,
    )
