"""tracecheck — static + runtime enforcement of the engine's contracts.

Two halves:

* **Static** (stdlib-only, no jax import): the AST rule engine
  (:mod:`.engine`, :mod:`.rules`, :mod:`.config`) and the import-graph
  report (:mod:`.imports`), driven by ``python -m repro.analysis``.
* **Runtime** (:mod:`.guard`): ``host_read``/``host_stage`` sanctioned
  transfer points re-exported from :mod:`repro.core.engine`, plus the
  pytest fixtures that run fits under ``jax.transfer_guard("disallow")``
  and assert the one-dispatch-per-phase ledger.

Rule catalogue and suppression policy: docs/design.md #9.
"""

from .config import Config, default_config
from .engine import Finding, Report, analyze_file, run

__all__ = ["Config", "default_config", "Finding", "Report",
           "analyze_file", "run"]
