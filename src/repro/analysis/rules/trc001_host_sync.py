"""TRC001 — host-sync calls on traced values in jit-reachable code.

``.item()``, ``float()/int()/bool()`` and ``np.asarray()/np.array()``
force a device→host transfer; inside a traced function they either fail
at trace time or (worse, via weak-typing edge cases) silently sink the
value to host and break the one-dispatch-per-phase discipline.  Host
orchestration code (``fit`` drivers) is not jit-reachable and may sync
freely — the sanctioned read points are ``engine.host_read`` /
``engine.host_stage``.
"""

from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, ModuleContext

_BUILTIN_SYNCS = ("float", "int", "bool", "complex")
_NUMPY_SYNCS = ("numpy.asarray", "numpy.array", "numpy.copy")


class TRC001:
    rule_id = "TRC001"
    title = ("host-sync call (.item()/float()/bool()/np.asarray) inside a "
             "jit-reachable function")

    def check(self, ctx: ModuleContext, config) -> List[Finding]:
        out: List[Finding] = []
        for info in ctx.reachable_functions():
            for node in ctx.walk_own(info.node):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if (isinstance(f, ast.Attribute) and f.attr == "item"
                        and not node.args and not node.keywords):
                    out.append(ctx.finding(
                        self.rule_id, node,
                        ".item() forces a device→host sync under the trace; "
                        "keep the value on device or read it via "
                        "engine.host_read at the phase boundary",
                        info.qualname))
                    continue
                r = ctx.resolve(f)
                if r in _NUMPY_SYNCS:
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"{r}() on a traced value falls back to host numpy; "
                        "use jnp inside traced code and engine.host_read at "
                        "the boundary", info.qualname))
                elif (isinstance(f, ast.Name) and f.id in _BUILTIN_SYNCS
                      and r == f.id and node.args
                      and not isinstance(node.args[0], ast.Constant)):
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"{f.id}() on a traced value is a concretization "
                        "sync; keep scalars as 0-d arrays on device",
                        info.qualname))
        return out
