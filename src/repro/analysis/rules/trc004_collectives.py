"""TRC004 — collectives inside ``StatsBackend`` implementations.

The StatsBackend contract (core/engine.py) is *collective-free*: a
backend computes per-shard partial sums and the distributed layer owns
the single ``psum`` composition point.  A collective inside a backend
would double-reduce under ``shard_map``, silently diverge the sharded
ledger from the local one, and break single-device fits outside any
mesh.  The rule fires on any ``jax.lax`` collective lexically inside a
class whose name (or base class name) ends in ``StatsBackend``.
"""

from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, ModuleContext

_COLLECTIVES = (
    "jax.lax.psum", "jax.lax.pmean", "jax.lax.pmax", "jax.lax.pmin",
    "jax.lax.all_gather", "jax.lax.all_to_all", "jax.lax.ppermute",
    "jax.lax.pshuffle", "jax.lax.psum_scatter", "jax.lax.axis_index",
)


class TRC004:
    rule_id = "TRC004"
    title = "collective (psum/pmean/all_gather/...) inside a StatsBackend"

    @staticmethod
    def _is_backend_class(node: ast.ClassDef, ctx: ModuleContext) -> bool:
        if node.name.endswith("StatsBackend"):
            return True
        for base in node.bases:
            r = ctx.resolve(base)
            if r and r.rsplit(".", 1)[-1].endswith("StatsBackend"):
                return True
        return False

    def check(self, ctx: ModuleContext, config) -> List[Finding]:
        out: List[Finding] = []
        for cls in ast.walk(ctx.tree):
            if not (isinstance(cls, ast.ClassDef)
                    and self._is_backend_class(cls, ctx)):
                continue
            for node in ast.walk(cls):
                if not isinstance(node, ast.Call):
                    continue
                r = ctx.resolve(node.func)
                if r in _COLLECTIVES:
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"{r}() inside StatsBackend `{cls.name}` — backends "
                        "are collective-free by contract; the distributed "
                        "layer owns the single psum composition point",
                        cls.name))
        return out
