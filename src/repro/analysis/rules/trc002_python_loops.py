"""TRC002 — Python ``for``/``while`` inside jit-reachable code.

A Python loop under a trace unrolls into the jit program: compile time
scales with the trip count, data-dependent bounds fail outright, and
the engine's contract (docs/design.md #1/#5) is
``lax.fori_loop``/``while_loop``/``scan``.  Trace-constant unrolls
(static chunking over shapes, fixed-depth RNG chain folds) are the
legitimate exception and must be suppressed with a justification.
"""

from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, ModuleContext


class TRC002:
    rule_id = "TRC002"
    title = "Python for/while loop unrolled inside a jit-reachable function"

    def check(self, ctx: ModuleContext, config) -> List[Finding]:
        out: List[Finding] = []
        for info in ctx.reachable_functions():
            for node in ctx.walk_own(info.node):
                if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
                    kind = "while" if isinstance(node, ast.While) else "for"
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"Python `{kind}` unrolls into the jit trace; the "
                        "engine contract is lax.fori_loop/while_loop/scan "
                        "(suppress only for trace-constant unrolls, with a "
                        "justification)", info.qualname))
        return out
