"""tracecheck rule pack — one module per TRC rule.

Rule objects expose ``rule_id``, ``title`` and
``check(ctx, config) -> list[Finding]``.  The engine handles path
scopes (except TRC005, whose sub-checks carry their own scopes) and
suppression filtering; rules only emit.
"""

from .trc001_host_sync import TRC001
from .trc002_python_loops import TRC002
from .trc003_rng_chain import TRC003
from .trc004_collectives import TRC004
from .trc005_parity import TRC005

ALL_RULES = (TRC001(), TRC002(), TRC003(), TRC004(), TRC005())

RULE_DOCS = {r.rule_id: r.title for r in ALL_RULES}
RULE_DOCS["TRC000"] = "suppression comment without a `-- reason` justification"

__all__ = ["ALL_RULES", "RULE_DOCS",
           "TRC001", "TRC002", "TRC003", "TRC004", "TRC005"]
