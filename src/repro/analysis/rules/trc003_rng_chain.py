"""TRC003 — raw PRNGKeys outside the sanctioned fold_in chain heads.

Every random draw in the engine must derive its key through the
documented ``(seed, phase, selection, round, shard)`` ``fold_in`` chain.
A raw ``jax.random.PRNGKey(...)`` anywhere else is exactly the shape of
the PR-4 sharded round-collision bug: a draw keyed on local state
(there, ``ref_idx[0]``) that ignored the round counter, so different
rounds silently reused reference subsets.  The chain heads — one per
driver — are listed in ``Config.sanctioned_key_constructors``.
"""

from __future__ import annotations

import ast
from typing import List

from ..engine import Finding, ModuleContext

_KEY_CONSTRUCTORS = ("jax.random.PRNGKey", "jax.random.key")
# Derivations that keep a chain a chain — not draws.
_CHAIN_OPS = ("jax.random.fold_in", "jax.random.split",
              "jax.random.clone", "jax.random.wrap_key_data")


class TRC003:
    rule_id = "TRC003"
    title = "raw PRNGKey outside the sanctioned fold_in chain constructors"

    @staticmethod
    def _sanctioned(qualname: str, config) -> bool:
        for s in config.sanctioned_key_constructors:
            if qualname == s or qualname.endswith("." + s):
                return True
        return False

    def check(self, ctx: ModuleContext, config) -> List[Finding]:
        out: List[Finding] = []
        for node, scope in ctx.walk_scoped():
            if not isinstance(node, ast.Call):
                continue
            r = ctx.resolve(node.func)
            if r in _KEY_CONSTRUCTORS:
                if not self._sanctioned(scope, config):
                    where = scope or "<module>"
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"raw {r}() in `{where}`, which is not a sanctioned "
                        "chain constructor — derive keys via fold_in/split "
                        "from the (seed, phase, selection, round, shard) "
                        "chain (PR-4 round-collision bug shape)", scope))
            elif (r and r.startswith("jax.random.")
                  and r not in _KEY_CONSTRUCTORS + _CHAIN_OPS):
                key_arg = node.args[0] if node.args else None
                if (isinstance(key_arg, ast.Call)
                        and ctx.resolve(key_arg.func) in _KEY_CONSTRUCTORS):
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"{r}() keyed on a fresh PRNGKey — the draw ignores "
                        "the fold_in chain, so distinct call sites/rounds "
                        "can silently collide", scope))
        return out
