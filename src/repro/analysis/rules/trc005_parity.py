"""TRC005 — bit-parity breakers, three sub-checks with their own scopes.

* ``vmap`` in the batch drivers (``core/banditpam.py``): the PR-6
  multi-fit contract is ``lax.map`` lanes that replay the single-fit
  HLO bit-for-bit; ``vmap`` re-vectorizes reductions and changes
  accumulation order.  (The threefry RNG helpers are the documented,
  suppressed exception — key derivation is bit-stable under vmap.)
* ``.at[...].set(inf)`` masking on streaming paths
  (``core/engine.py``, ``kernels/``): the PR-8 megakernel replaced
  materialize-then-mask top-2 with online (min, min2) accumulation;
  an ``at[].set(inf)`` copy resurrects the O(n·b) temp the peak-temp
  gate bans, and the copy's schedule is not tile-order pinned.
* f64→f32 casts in host accounting (``serve/drift.py``,
  ``runtime/checkpoint.py``): drift statistics and checkpoint leaf
  round-trips are contractually f64/bit-exact; a stray ``float32``
  constructor or dtype-less ``jnp.asarray`` silently rounds them.

All three report as TRC005 and share the suppression token.
"""

from __future__ import annotations

import ast
from typing import List

from ..config import path_in_scope
from ..engine import Finding, ModuleContext

_INF_NAMES = ("jax.numpy.inf", "numpy.inf", "math.inf")
_F32_CONSTRUCTORS = ("numpy.float32", "jax.numpy.float32")
_DTYPELESS_CONVERTERS = ("jax.numpy.asarray", "jax.numpy.array")


def _is_inf(node: ast.AST, ctx: ModuleContext) -> bool:
    if isinstance(node, (ast.Name, ast.Attribute)):
        return ctx.resolve(node) in _INF_NAMES
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        if (ctx.resolve(node.func) == "float" and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "inf"):
            return True
    return False


class TRC005:
    rule_id = "TRC005"
    title = "bit-parity breaker (vmap batch lane / at[].set(inf) / f32 cast)"

    def check(self, ctx: ModuleContext, config) -> List[Finding]:
        out: List[Finding] = []
        if path_in_scope(ctx.path, config.trc005_vmap):
            out.extend(self._check_vmap(ctx))
        if path_in_scope(ctx.path, config.trc005_setinf):
            out.extend(self._check_setinf(ctx))
        if path_in_scope(ctx.path, config.trc005_f32):
            out.extend(self._check_f32(ctx))
        return out

    def _check_vmap(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node, scope in ctx.walk_scoped():
            if isinstance(node, ast.Call) and ctx.resolve(
                    node.func) == "jax.vmap":
                out.append(ctx.finding(
                    self.rule_id, node,
                    "jax.vmap in a batch driver — the multi-fit parity "
                    "contract is lax.map lanes replaying the single-fit "
                    "HLO (docs/design.md #6); vmap changes reduction "
                    "order", scope))
        return out

    def _check_setinf(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node, scope in ctx.walk_scoped():
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "set"
                    and isinstance(node.func.value, ast.Subscript)
                    and isinstance(node.func.value.value, ast.Attribute)
                    and node.func.value.value.attr == "at"):
                continue
            if node.args and _is_inf(node.args[0], ctx):
                out.append(ctx.finding(
                    self.rule_id, node,
                    ".at[...].set(inf) masking materializes a full copy on "
                    "a streaming path — use online (min, min2) accumulation "
                    "or a where-mask inside the tile walk "
                    "(docs/design.md #8)", scope))
        return out

    def _check_f32(self, ctx: ModuleContext) -> List[Finding]:
        out = []
        for node, scope in ctx.walk_scoped():
            if not isinstance(node, ast.Call):
                continue
            r = ctx.resolve(node.func)
            if r in _F32_CONSTRUCTORS:
                out.append(ctx.finding(
                    self.rule_id, node,
                    f"{r}() in an f64 host-accounting module silently "
                    "rounds drift/checkpoint state to f32", scope))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "astype" and node.args):
                a = node.args[0]
                tgt = ctx.resolve(a) if isinstance(
                    a, (ast.Name, ast.Attribute)) else (
                        a.value if isinstance(a, ast.Constant) else None)
                if tgt in _F32_CONSTRUCTORS + ("float32",):
                    out.append(ctx.finding(
                        self.rule_id, node,
                        ".astype(float32) in an f64 host-accounting module "
                        "silently rounds drift/checkpoint state", scope))
            elif r in _DTYPELESS_CONVERTERS:
                has_dtype = len(node.args) > 1 or any(
                    kw.arg == "dtype" for kw in node.keywords)
                if not has_dtype:
                    out.append(ctx.finding(
                        self.rule_id, node,
                        f"dtype-less {r}() in an f64 host-accounting module "
                        "casts float64 host state to the default f32 — pass "
                        "an explicit dtype", scope))
        return out
