"""graphcheck — compiled-graph contract analyzer (docs/design.md #10).

tracecheck (``repro.analysis``) lints the *source*; this package audits
the *compiled programs* the source actually produces.  ``entrypoints``
registers every hot jitted program at canonical symbolic shapes,
``rules`` runs the GRC000–GRC006 contracts over their jaxprs/lowered
text/compiled memory analyses, ``budgets`` declares the peak-temp byte
bounds, and ``fingerprint`` maintains the version-keyed golden op-census
artifact at ``tests/fixtures/graphs.json``.

CLI: ``python -m repro.analysis.graph`` (see ``--help``).
"""

from .rules import ALL_RULES, Finding, Report, RULE_DOCS, analyze

__all__ = ["ALL_RULES", "RULE_DOCS", "Finding", "Report", "analyze"]
