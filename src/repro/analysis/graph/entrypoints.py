"""graphcheck entrypoint registry — every hot compiled program, by name.

Each :class:`GraphSpec` names one compiled program the repo dispatches on
a hot path and knows how to *lower* it at two shape points:

* ``build()`` — the canonical SMALL shapes (``N`` = 640 rows, one step
  past the ``_EXACT_CHUNK`` = 512 reference tile so every streaming walk
  actually loops).  The jaxpr rules (GRC002/3/4/6), the donation check
  (GRC005, read off the lowered StableHLO) and the golden op-census
  fingerprint all run here — tracing is cheap, so the full registry is
  analysed on every run.
* ``build_big()`` — the declared budget shapes (GRC001 only): the
  program is lowered AND compiled so ``memory_analysis()`` can bound the
  peak temp against the ``budgets.py`` declaration.  Only entrypoints
  with a ``budget`` key pay this.

The registry is the contract surface: adding a hot dispatch to the repo
means adding a spec here (the self-check test asserts the known driver
names stay registered), and every declared number — collective census,
donated leaf count, narrowing-convert allowance, byte budget — is data
that the rules enforce against the *compiled artifact*, not the source.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from . import budgets

__all__ = ["GraphSpec", "registry", "N", "D", "K", "B", "WIDTH", "BF", "T"]

# Canonical small shapes.  N sits one step past the 512-row reference
# tile so fori/dynamic-slice streaming walks take >1 step; every other
# axis (k, d, batch width, ring width, fit count) stays far below N so a
# materialised [n, n]-class block is unambiguous to GRC002.
N, D, K = 640, 8, 8
B = 32            # bandit batch (reference columns per round)
W_ROUNDS = 2      # PIC ring round capacity at registry shapes
WIDTH = W_ROUNDS * B
BF = 2            # batched multi-fit lane count
T = 3             # batched multi-fit max_swaps
RB = -(-N // B) * B


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """One registered compiled program + its declared contracts."""

    name: str
    # () -> (lowerable fn, positional args, kwargs incl. static argnames)
    build: Callable[[], Tuple]
    # {"streaming", "hot", "kernel", "batch", "sharded"}
    tags: frozenset
    # the dataset axis at registry shapes: GRC002 flags any intermediate
    # whose aval has >= 2 axes of at least this extent
    n: int = N
    # declared collective census over the whole jaxpr (GRC003);
    # absent keys mean zero
    collectives: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # number of array leaves that must carry a tf.aliasing_output
    # attribute in the lowered program (GRC005); 0 = nothing donated
    donated_leaves: int = 0
    # audited narrowing float->float converts (GRC006); 0 = none allowed
    allowed_narrowing: int = 0
    # budgets.py key (GRC001); None = no compiled-memory gate
    budget: Optional[str] = None
    # () -> (fn, args, kwargs) at the budget shapes; required iff budget
    build_big: Optional[Callable[[], Tuple]] = None


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def _bool(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bool_)


def _driver_statics(**over):
    kw = dict(backend="jnp", metric="l2", batch_size=B,
              delta=1.0 / (1000.0 * N), sampling="permutation",
              baseline="none", k=K, mode="none", free_rounds=0)
    kw.update(over)
    return kw


def _pic_cache_avals(bf: Optional[int] = None):
    from repro.core.pic_cache import PicCache
    if bf is None:
        return PicCache(cols=_f32(N, WIDTH), hw=_i32(), fresh_pos=_u32())
    return PicCache(cols=_f32(bf, N, WIDTH), hw=_i32(bf),
                    fresh_pos=_u32(bf))


# -- core drivers -----------------------------------------------------------

def _build_fused(mode: str):
    def build():
        from repro.core import banditpam as bp
        kw = _driver_statics(mode=mode)
        if mode == "pic":
            args = (_f32(N, D), _u32(K, 2), _pic_cache_avals(), None,
                    _i32(N))
        else:
            args = (_f32(N, D), _u32(K, 2), None, None, None)
        return bp._build_fused, args, kw
    return build


def _swap_iter(mode: str):
    def build():
        from repro.core import banditpam as bp
        kw = _driver_statics(mode=mode, delta=1.0 / (1000.0 * K * N),
                             early_stop=False)
        if mode == "pic":
            carry = (_f32(K * N), _f32(K * N), _i32(), _f32(N), _f32(N),
                     _i32(N))
            args = (_f32(N, D), _i32(K), _bool(N), _u32(2),
                    _pic_cache_avals(), None, _i32(N), _i32(WIDTH),
                    _f32(WIDTH), carry, _f32())
        else:
            args = (_f32(N, D), _i32(K), _bool(N), _u32(2), None, None,
                    None, None, None, None, _f32())
        return bp._swap_iter_jit, args, kw
    return build


def _build_batch():
    from repro.core import banditpam as bp
    kw = _driver_statics(mode="pic", delta=None)
    args = (_f32(BF, N, D), _u32(BF, K, 2), _pic_cache_avals(BF),
            _i32(BF, RB), _f32(BF, RB), _bool(BF, N), _i32(BF),
            _f32(BF))
    return bp._build_batch, args, kw


def _swap_batch():
    from repro.core import banditpam as bp
    kw = _driver_statics(mode="pic", delta=None, early_stop=False,
                         max_swaps=T)
    args = (_f32(BF, N, D), _i32(BF, K), _bool(BF, N), _u32(BF, T, 2),
            _pic_cache_avals(BF), _i32(BF, WIDTH), _f32(BF, WIDTH),
            _i32(BF, RB), _f32(BF, RB), _bool(BF, N), _i32(BF), _f32(BF))
    return bp._swap_batch, args, kw


# -- engine streaming helpers ----------------------------------------------

def _engine_fn(name: str, big: bool = False):
    import numpy as np  # noqa: F401  (kept for symmetry with _dist)
    from repro.core import engine
    n, d, k = ((budgets.N_BIG, budgets.D_BIG, budgets.K_BIG) if big
               else (N, D, K))
    if name == "total_loss":
        fn = jax.jit(functools.partial(engine.total_loss, metric="l2"))
        return fn, (_f32(n, d), _i32(k)), {}
    if name == "medoid_cache":
        fn = jax.jit(functools.partial(engine.medoid_cache, metric="l2"))
        return fn, (_f32(n, d), _i32(k)), {}
    be = engine.get_stats_backend("jnp")
    if name == "exact_build_means":
        fn = jax.jit(lambda data, dn: engine.exact_build_means(
            be, data, dn, metric="l2"))
        return fn, (_f32(n, d), _f32(n)), {}
    assert name == "exact_swap_means"
    fn = jax.jit(lambda data, d1, d2, a: engine.exact_swap_means(
        be, data, d1, d2, a, k, metric="l2"))
    return fn, (_f32(n, d), _f32(n), _f32(n), _i32(n)), {}


# -- pallas streaming kernels (interpret mode off-TPU) ----------------------

def _stream_kernel(name: str, big: bool = False):
    from repro.kernels import ops
    n, d = (budgets.N_BIG, budgets.D_BIG) if big else (N, D)
    m = 256 if big else 64
    if name == "build":
        fn = jax.jit(lambda x, y, dn, w, lg: ops.stream_build_g_stats(
            x, y, dn, w, lg, metric="l2sq", interpret=True))
        return fn, (_f32(m, d), _f32(n, d), _f32(n), _f32(n), _f32(n)), {}
    if name == "swap":
        fn = jax.jit(lambda x, y, d1, d2, a, w, lg: ops.stream_swap_g_stats(
            x, y, d1, d2, a, w, K, lg, metric="l2sq", interpret=True))
        return fn, (_f32(m, d), _f32(n, d), _f32(n), _f32(n), _i32(n),
                    _f32(n), _f32(n)), {}
    assert name == "top2"
    fn = jax.jit(lambda x, med: ops.stream_top2(
        x, med, metric="l2sq", interpret=True))
    return fn, (_f32(n, d), _f32(K, d)), {}


# -- serving closures -------------------------------------------------------

def _predict_fn(big: bool = False):
    from repro.api import predict
    rows = budgets.ROWS_PREDICT if big else 256
    k, d = (budgets.K_BIG, budgets.D_BIG) if big else (K, D)
    fn = predict.get_predict_fn(k, d, "l2", "jnp", rows)
    return fn, (_f32(rows, d), _f32(k, d)), {}


def _assign_fn(big: bool = False):
    from repro.api import predict
    rows = budgets.ROWS_ASSIGN if big else 1024
    k, d = (budgets.K_BIG, budgets.D_BIG) if big else (K, D)
    fn = predict.get_assign_fn(k, d, "l2", "jnp", rows)
    return fn, (_f32(rows, d), _f32(k, d)), {}


# -- sharded phases ---------------------------------------------------------

def _dist_phase(which: str):
    def build():
        import numpy as np
        from repro.core.distributed import DistributedBanditPAM, default_mesh
        from repro.core.engine import (get_stats_backend,
                                       resolve_stats_backend)
        est = DistributedBanditPAM(K, default_mesh(), batch_size=B,
                                   reuse="pic", cache_width=WIDTH, seed=0)
        be = get_stats_backend(resolve_stats_backend(est.backend,
                                                     est.metric))
        rng = np.random.default_rng(0)
        data = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
        data_sh = est._shard_data(data)
        key = jax.random.PRNGKey(0)
        key, ckey = jax.random.split(key)
        lperm, lw, pidx_g, pw_g, cache, w_r = est._pic_layout(N, ckey)
        if which == "build":
            fn = est._make_build_phase(be, N, 1.0 / (1000.0 * N), w_r)
            subs = jnp.stack([jax.random.PRNGKey(i) for i in range(K)])
            args = (data, data_sh, jax.random.PRNGKey(7), subs, lperm,
                    lw, pidx_g, pw_g, cache)
        else:
            fn = est._make_swap_iter(be, N, 1.0 / (1000.0 * K * N), w_r)
            med = jnp.arange(K, dtype=jnp.int32) * (N // K)
            mask = jnp.zeros((N,), jnp.bool_).at[med].set(True)
            args = (data, data_sh, med, mask, jax.random.PRNGKey(3),
                    jax.random.PRNGKey(4), lperm, lw, pidx_g, pw_g,
                    cache, None)
        return fn, args, {}
    return build


# -- the registry -----------------------------------------------------------

_HOT = frozenset({"hot"})
_STREAM = frozenset({"hot", "streaming"})
_KERNEL = frozenset({"hot", "streaming", "kernel"})
_BATCH = frozenset({"hot", "streaming", "batch"})
_SHARDED = frozenset({"hot", "streaming", "sharded"})

# The sharded phases run one fori_loop-resident shard_map with three
# moment reductions (sums / sqsums / cross-term) — the census is 3 psums
# through 1 shard_map site, NOT one psum per phase: FastPAM1 sharing
# needs all three moments per round (docs/design.md #4/#10).
_SMAP_CENSUS = {"psum": 3, "shard_map": 1}


def registry() -> Tuple[GraphSpec, ...]:
    """The shipped entrypoint set, one spec per hot compiled program."""
    return (
        GraphSpec("core._build_fused[none]", _build_fused("none"), _STREAM),
        GraphSpec("core._build_fused[pic]", _build_fused("pic"), _STREAM,
                  donated_leaves=3,
                  budget="core._build_fused[pic]",
                  build_big=_big_driver_build),
        GraphSpec("core._swap_iter[none]", _swap_iter("none"), _STREAM),
        GraphSpec("core._swap_iter[pic]", _swap_iter("pic"), _STREAM,
                  donated_leaves=9,
                  budget="core._swap_iter[pic]",
                  build_big=_big_driver_swap),
        GraphSpec("core._build_batch[pic]", _build_batch, _BATCH),
        GraphSpec("core._swap_batch[pic]", _swap_batch, _BATCH),
        GraphSpec("engine.total_loss",
                  lambda: _engine_fn("total_loss"), _STREAM,
                  budget="engine.total_loss",
                  build_big=lambda: _engine_fn("total_loss", big=True)),
        GraphSpec("engine.medoid_cache",
                  lambda: _engine_fn("medoid_cache"), _STREAM,
                  budget="engine.medoid_cache",
                  build_big=lambda: _engine_fn("medoid_cache", big=True)),
        GraphSpec("engine.exact_build_means",
                  lambda: _engine_fn("exact_build_means"), _STREAM,
                  budget="engine.exact_build_means",
                  build_big=lambda: _engine_fn("exact_build_means",
                                               big=True)),
        GraphSpec("engine.exact_swap_means",
                  lambda: _engine_fn("exact_swap_means"), _STREAM,
                  budget="engine.exact_swap_means",
                  build_big=lambda: _engine_fn("exact_swap_means",
                                               big=True)),
        GraphSpec("kernels.stream_build_g_stats",
                  lambda: _stream_kernel("build"), _KERNEL,
                  budget="kernels.stream_build_g_stats",
                  build_big=lambda: _stream_kernel("build", big=True)),
        GraphSpec("kernels.stream_swap_g_stats",
                  lambda: _stream_kernel("swap"), _KERNEL,
                  budget="kernels.stream_swap_g_stats",
                  build_big=lambda: _stream_kernel("swap", big=True)),
        GraphSpec("kernels.stream_top2",
                  lambda: _stream_kernel("top2"), _KERNEL,
                  budget="kernels.stream_top2",
                  build_big=lambda: _stream_kernel("top2", big=True)),
        # get_predict_fn RETURNS the [rows, k] block — materialising it is
        # the product, so no "streaming" tag; the budget bounds the temps
        # AROUND that block instead of forbidding it.
        GraphSpec("api.get_predict_fn", _predict_fn, _HOT,
                  budget="api.get_predict_fn",
                  build_big=lambda: _predict_fn(big=True)),
        GraphSpec("api.get_assign_fn", _assign_fn, _STREAM,
                  budget="api.get_assign_fn",
                  build_big=lambda: _assign_fn(big=True)),
        GraphSpec("dist.build_phase[pic]", _dist_phase("build"), _SHARDED,
                  collectives=_SMAP_CENSUS),
        GraphSpec("dist.swap_iter[pic]", _dist_phase("swap"), _SHARDED,
                  collectives=_SMAP_CENSUS),
    )


def _big_driver_build():
    from repro.core import banditpam as bp
    n, d, k = budgets.N_DRIVER, budgets.D_DRIVER, budgets.K_DRIVER
    width = budgets.WIDTH_DRIVER
    from repro.core.pic_cache import PicCache
    cache = PicCache(cols=_f32(n, width), hw=_i32(), fresh_pos=_u32())
    kw = dict(backend="jnp", metric="l2", batch_size=B,
              delta=1.0 / (1000.0 * n), sampling="permutation",
              baseline="none", k=k, mode="pic", free_rounds=0)
    return bp._build_fused, (_f32(n, d), _u32(k, 2), cache, None,
                             _i32(n)), kw


def _big_driver_swap():
    from repro.core import banditpam as bp
    n, d, k = budgets.N_DRIVER, budgets.D_DRIVER, budgets.K_DRIVER
    width = budgets.WIDTH_DRIVER
    from repro.core.pic_cache import PicCache
    cache = PicCache(cols=_f32(n, width), hw=_i32(), fresh_pos=_u32())
    carry = (_f32(k * n), _f32(k * n), _i32(), _f32(n), _f32(n), _i32(n))
    kw = dict(backend="jnp", metric="l2", batch_size=B,
              delta=1.0 / (1000.0 * k * n), sampling="permutation",
              baseline="none", k=k, mode="pic", free_rounds=0,
              early_stop=False)
    return bp._swap_iter_jit, (_f32(n, d), _i32(k), _bool(n), _u32(2),
                               cache, None, _i32(n), _i32(width),
                               _f32(width), carry, _f32()), kw


def by_name() -> Dict[str, GraphSpec]:
    return {s.name: s for s in registry()}
