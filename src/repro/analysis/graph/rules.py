"""graphcheck rule engine — compiled-graph contracts GRC000–GRC006.

tracecheck (docs/design.md #9) polices what the *source* may say; these
rules police what the *compiled program* actually is.  Every rule runs
against artifacts jax hands back for a registered entrypoint — the
ClosedJaxpr, the lowered StableHLO text, and (for budgets) the compiled
executable's memory analysis:

* GRC000 fingerprint drift — the trace-level op census at the canonical
  registry shapes no longer matches the committed golden for the
  running jax version (reported with a primitive-level diff).
* GRC001 memory budget — ``memory_analysis().temp_size_in_bytes`` at
  the declared big shapes exceeds the ``budgets.py`` bound.
* GRC002 materialisation — a streaming entrypoint holds an intermediate
  with >= 2 axes at dataset extent (the [n, n]-class block the whole
  streaming architecture exists to avoid).
* GRC003 collective census — psum/shard_map counts differ from the
  spec's declaration (zero for single-device entrypoints: a collective
  smuggled into backend code is the runtime twin of TRC004).
* GRC004 transfer census — any device_put/callback/infeed-class
  primitive inside a hot trace (each one is a host round-trip the fused
  dispatch was supposed to have absorbed).
* GRC005 donation — fewer ``tf.aliasing_output`` attributes in the
  lowered program than declared donated leaves (a lost donation doubles
  the carry footprint silently).
* GRC006 dtype discipline — more narrowing float->float
  ``convert_element_type`` ops than the spec's audited allowance
  (silent precision loss inside reduction chains).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

import jax

from . import budgets as budgets_mod
from . import fingerprint as fp
from .entrypoints import GraphSpec, registry

__all__ = ["Finding", "Report", "ALL_RULES", "RULE_DOCS", "analyze",
           "format_human", "report_to_json"]

ALL_RULES = ("GRC000", "GRC001", "GRC002", "GRC003", "GRC004", "GRC005",
             "GRC006")

RULE_DOCS = {
    "GRC000": "golden fingerprint drift (op census changed at canonical "
              "shapes)",
    "GRC001": "compiled peak-temp exceeds the declared memory budget",
    "GRC002": "materialised [n, n]-class intermediate in a streaming "
              "entrypoint",
    "GRC003": "collective census differs from the declared psum/shard_map "
              "counts",
    "GRC004": "transfer-class primitive (device_put/callback/infeed) in a "
              "hot trace",
    "GRC005": "declared donated buffers do not alias in the lowered "
              "program",
    "GRC006": "unaudited narrowing float convert in the trace",
}

# Primitives that cross the host<->device boundary from inside a trace.
TRANSFER_PRIMS = frozenset({
    "device_put", "pure_callback", "io_callback", "debug_callback",
    "callback", "infeed", "outfeed", "copy_to_host_async",
})

# Collectives counted by GRC003; jax spells the all-reduce `psum` or
# `psum2` depending on the axis-name context, one declared key covers
# both.
COLLECTIVE_PRIMS = {"psum": ("psum", "psum2"),
                    "shard_map": ("shard_map",)}

_FLOAT_BITS = {"float64": 64, "float32": 32, "float16": 16,
               "bfloat16": 16}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    entrypoint: str
    message: str


@dataclasses.dataclass
class Report:
    findings: List[Finding]
    entrypoints: List[str]
    notes: List[str]
    skipped_budgets: bool = False

    @property
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


def _narrowing(converts: Iterable) -> List:
    out = []
    for src, dst in converts:
        sb, db = _FLOAT_BITS.get(src), _FLOAT_BITS.get(dst)
        if sb is not None and db is not None and db < sb:
            out.append((src, dst))
    return out


def _check_jaxpr_rules(spec: GraphSpec, sv: fp.Survey,
                       findings: List[Finding]) -> None:
    # GRC002 — materialisation in streaming entrypoints
    if "streaming" in spec.tags:
        seen = set()
        for prim, shape in sv.big_outs:
            big_axes = sum(1 for s in shape if s >= spec.n)
            if big_axes >= 2 and (prim, shape) not in seen:
                seen.add((prim, shape))
                findings.append(Finding(
                    "GRC002", spec.name,
                    f"materialised intermediate {list(shape)} from "
                    f"'{prim}' (>= 2 axes at dataset extent n={spec.n})"))
    # GRC003 — collective census
    for prim, spellings in COLLECTIVE_PRIMS.items():
        declared = int(spec.collectives.get(prim, 0))
        got = sum(sv.census.get(s, 0) for s in spellings)
        if got != declared:
            findings.append(Finding(
                "GRC003", spec.name,
                f"{prim} count {got} != declared {declared}"))
    # GRC004 — transfer census (const-staged device_puts are constant
    # placement, not runtime round-trips; Survey separates them)
    for prim in sorted(TRANSFER_PRIMS & set(sv.census)):
        count = sv.runtime_puts if prim == "device_put" \
            else sv.census[prim]
        if count > 0:
            findings.append(Finding(
                "GRC004", spec.name,
                f"transfer primitive '{prim}' x{count} inside a hot "
                f"trace"))
    # GRC006 — narrowing converts
    narrowing = _narrowing(sv.converts)
    if len(narrowing) > spec.allowed_narrowing:
        findings.append(Finding(
            "GRC006", spec.name,
            f"{len(narrowing)} narrowing float convert(s) "
            f"{sorted(set(narrowing))}, allowance "
            f"{spec.allowed_narrowing}"))


def _check_donation(spec: GraphSpec, lowered_text: str,
                    findings: List[Finding]) -> None:
    if spec.donated_leaves <= 0:
        return
    got = lowered_text.count("tf.aliasing_output")
    if got < spec.donated_leaves:
        findings.append(Finding(
            "GRC005", spec.name,
            f"{got} aliased buffer(s) in the lowered program, declared "
            f"{spec.donated_leaves} donated leaves — a donation was "
            f"dropped"))


def _check_budget(spec: GraphSpec, findings: List[Finding],
                  notes: List[str]) -> None:
    fn, args, kw = spec.build_big()
    compiled = fn.lower(*args, **kw).compile()
    ma = compiled.memory_analysis()
    if ma is None or not hasattr(ma, "temp_size_in_bytes"):
        notes.append(f"{spec.name}: memory_analysis unavailable on this "
                     f"backend; GRC001 not evaluated")
        return
    temp = int(ma.temp_size_in_bytes)
    bound = budgets_mod.budget_bytes(spec.budget)
    if temp > bound:
        findings.append(Finding(
            "GRC001", spec.name,
            f"compiled peak temp {temp:,} B exceeds budget {bound:,} B "
            f"[{budgets_mod.budget_doc(spec.budget)}] at "
            f"{budgets_mod.shape_for(spec.budget)}"))


def _check_drift(spec: GraphSpec, print_doc: Dict, golden_doc,
                 findings: List[Finding], notes: List[str]) -> None:
    vgold = fp.golden_for_version(golden_doc)
    if vgold is None:
        return  # version-level note emitted once by analyze()
    old = vgold.get(spec.name)
    if old is None:
        findings.append(Finding(
            "GRC000", spec.name,
            f"no committed golden fingerprint for jax "
            f"{jax.__version__} — regenerate with {fp.GOLDEN_ENV}=1"))
        return
    if old.get("hash") != print_doc.get("hash"):
        diff = fp.diff_fingerprints(old, print_doc)
        findings.append(Finding(
            "GRC000", spec.name,
            "compiled-graph drift vs committed golden:\n" + diff))


def analyze(specs: Optional[Sequence[GraphSpec]] = None, *,
            golden_doc: Optional[Dict] = None,
            rules: Optional[Sequence[str]] = None,
            with_budgets: bool = True) -> "tuple[Report, Dict[str, Dict]]":
    """Run the rule engine; returns (report, fingerprints-by-name)."""
    specs = registry() if specs is None else specs
    active = set(ALL_RULES if rules is None else rules)
    findings: List[Finding] = []
    notes: List[str] = []
    prints: Dict[str, Dict] = {}

    if "GRC000" in active and golden_doc is not None and \
            fp.golden_for_version(golden_doc) is None:
        notes.append(
            f"no goldens committed for jax {jax.__version__} "
            f"(have: {sorted(golden_doc.get('goldens', {}))}); "
            f"GRC000 drift not evaluated")

    for spec in specs:
        fn, args, kw = spec.build()
        traced = fn.trace(*args, **kw)
        closed = traced.jaxpr
        sv = fp.survey(closed)
        doc = fp.fingerprint(closed, sv)
        prints[spec.name] = doc

        ruled: List[Finding] = []
        _check_jaxpr_rules(spec, sv, ruled)
        if "GRC005" in active and spec.donated_leaves > 0:
            _check_donation(spec, traced.lower().as_text(), ruled)
        if "GRC001" in active and spec.budget is not None and with_budgets:
            _check_budget(spec, ruled, notes)
        if "GRC000" in active and golden_doc is not None:
            _check_drift(spec, doc, golden_doc, ruled, notes)
        findings.extend(f for f in ruled if f.rule in active)

    if not with_budgets:
        skipped = [s.name for s in specs if s.budget is not None]
        if skipped and "GRC001" in active:
            notes.append(f"budgets skipped for {len(skipped)} "
                         f"entrypoint(s) (--skip-budgets)")
    report = Report(findings=findings, entrypoints=[s.name for s in specs],
                    notes=notes, skipped_budgets=not with_budgets)
    return report, prints


def format_human(report: Report) -> str:
    lines = []
    for f in report.findings:
        lines.append(f"{f.rule} {f.entrypoint}: {f.message}")
    for n in report.notes:
        lines.append(f"note: {n}")
    lines.append(f"{len(report.findings)} finding(s) across "
                 f"{len(report.entrypoints)} entrypoint(s)")
    return "\n".join(lines)


def report_to_json(report: Report, prints: Optional[Dict] = None) -> Dict:
    doc = {
        "tool": "graphcheck",
        "version": 1,
        "jax": jax.__version__,
        "entrypoints": report.entrypoints,
        "counts": report.counts,
        "findings": [dataclasses.asdict(f) for f in report.findings],
        "notes": list(report.notes),
    }
    if prints is not None:
        doc["fingerprints"] = prints
    return doc
