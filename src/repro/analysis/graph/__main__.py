"""``python -m repro.analysis.graph`` — the graphcheck CLI.

Examples::

    python -m repro.analysis.graph
    python -m repro.analysis.graph --format json --output graphcheck.json
    python -m repro.analysis.graph --entrypoints core._build_fused[pic]
    python -m repro.analysis.graph --rules GRC003,GRC004 --skip-budgets
    REGEN_GOLDEN=1 python -m repro.analysis.graph
    python -m repro.analysis.graph --golden-diff

Exit codes: 0 clean, 1 findings, 2 usage error.  Unlike tracecheck this
CLI imports jax — it traces, lowers, and (without ``--skip-budgets``)
compiles every registered entrypoint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.graph",
        description="graphcheck: compiled-graph contract analyzer with "
                    "golden HLO fingerprints")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--output", metavar="FILE",
                        help="also write the JSON report to FILE")
    parser.add_argument("--rules", metavar="CSV",
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--entrypoints", metavar="CSV",
                        help="comma-separated registry names to analyze "
                             "(default: all)")
    parser.add_argument("--skip-budgets", action="store_true",
                        help="skip GRC001 big-shape compiles (fast trace-"
                             "only pass)")
    parser.add_argument("--golden", metavar="FILE",
                        help="golden fingerprint file (default: "
                             "tests/fixtures/graphs.json)")
    parser.add_argument("--golden-diff", action="store_true",
                        help="print the primitive-level diff vs the "
                             "golden and exit (0 = no drift)")
    parser.add_argument("--list-rules", action="store_true")
    parser.add_argument("--list-entrypoints", action="store_true")
    args = parser.parse_args(argv)

    # Rule/registry imports are deferred past --help so argparse errors
    # stay fast and jax-free.
    from . import fingerprint as fp
    from . import rules as rules_mod
    from .entrypoints import by_name, registry

    if args.list_rules:
        for rid in sorted(rules_mod.RULE_DOCS):
            print(f"{rid}: {rules_mod.RULE_DOCS[rid]}")
        return 0
    if args.list_entrypoints:
        for spec in registry():
            tags = ",".join(sorted(spec.tags))
            print(f"{spec.name}  [{tags}]")
        return 0

    rule_ids = None
    if args.rules:
        rule_ids = tuple(r.strip() for r in args.rules.split(",")
                         if r.strip())
        unknown = [r for r in rule_ids if r not in rules_mod.ALL_RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    specs = None
    if args.entrypoints:
        table = by_name()
        names = [s.strip() for s in args.entrypoints.split(",")
                 if s.strip()]
        unknown = [s for s in names if s not in table]
        if unknown:
            print(f"unknown entrypoint(s): {', '.join(unknown)} "
                  f"(see --list-entrypoints)", file=sys.stderr)
            return 2
        specs = [table[s] for s in names]

    golden_path = args.golden or fp.default_golden_path()
    golden_doc = None
    golden_note = None
    if golden_path and os.path.isfile(golden_path):
        golden_doc = fp.load_golden(golden_path)
    elif golden_path:
        golden_note = (f"no golden file at {golden_path}; GRC000 drift "
                       f"not evaluated (regenerate with "
                       f"{fp.GOLDEN_ENV}=1)")
    else:
        golden_note = ("golden path unresolvable (installed copy without "
                       "the tests tree); GRC000 drift not evaluated")

    regen = os.environ.get(fp.GOLDEN_ENV, "") not in ("", "0")

    report, prints = rules_mod.analyze(
        specs, golden_doc=None if regen else golden_doc,
        rules=rule_ids, with_budgets=not args.skip_budgets)
    if golden_note and not regen and \
            (rule_ids is None or "GRC000" in rule_ids):
        report.notes.append(golden_note)

    if regen:
        if not golden_path:
            print("cannot regenerate: golden path unresolvable",
                  file=sys.stderr)
            return 2
        if specs is not None:
            print("cannot regenerate from a partial --entrypoints run",
                  file=sys.stderr)
            return 2
        merged = fp.merge_golden(golden_doc, prints)
        fp.dump_golden(merged, golden_path)
        print(f"wrote {len(prints)} fingerprint(s) for jax "
              f"{__import__('jax').__version__} to {golden_path}")

    if args.golden_diff:
        drift = [f for f in report.findings if f.rule == "GRC000"]
        for f in drift:
            print(f"{f.entrypoint}:\n{f.message}")
        for n in report.notes:
            print(f"note: {n}")
        print(f"{len(drift)} drifted entrypoint(s)")
        return 1 if drift else 0

    doc = rules_mod.report_to_json(report, prints)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
    if args.format == "json":
        print(json.dumps(doc, indent=1, sort_keys=True))
    else:
        print(rules_mod.format_human(report))
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
