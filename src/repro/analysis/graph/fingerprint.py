"""Canonical compiled-graph fingerprints + the golden artifact.

A fingerprint is a *trace-level* identity for one registered entrypoint
at the registry's canonical shapes: the sorted primitive census of the
whole ClosedJaxpr (subjaxprs included — pjit bodies, scan/while carries,
cond branches, shard_map and pallas_call interiors) plus a short hash
over the per-equation ``(primitive, output shapes/dtypes)`` sequence and
the program's input/output avals.

Semantics (docs/design.md #10): the registry pins the shapes, so a
fingerprint change is graph DRIFT — somebody changed what the compiled
program *is* — never a retrace artifact.  Retraces happen at new shapes
and new shapes are not fingerprinted; the same source at the same shapes
always re-derives the same jaxpr (tracing is deterministic).  Goldens
are keyed by ``jax.__version__`` because the jaxpr a given source
lowers to legitimately differs across jax releases: a runner whose jax
version has no committed golden reports a note, not a finding.

The golden artifact lives at ``tests/fixtures/graphs.json``; regenerate
with ``REGEN_GOLDEN=1 python -m repro.analysis.graph`` (merges the
running version's entries, preserving other versions').
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Optional, Tuple

import jax

__all__ = ["Survey", "survey", "fingerprint", "diff_fingerprints",
           "load_golden", "merge_golden", "golden_for_version",
           "default_golden_path", "GOLDEN_ENV"]

GOLDEN_ENV = "REGEN_GOLDEN"


class Survey:
    """Everything one recursive jaxpr walk collects.

    * ``census`` — primitive name -> count, whole program.
    * ``eqn_sig`` — flat ``(primitive, out-aval-string)`` sequence in
      walk order (the hash substrate).
    * ``big_outs`` — ``(primitive, shape)`` for every equation output;
      the materialisation rule scans these.
    * ``converts`` — ``(in_dtype, out_dtype)`` per convert_element_type.
    * ``runtime_puts`` — device_put count EXCLUDING const staging.  A
      device_put whose inputs are all trace-time constants (Literals or
      constvars of the enclosing jaxpr — e.g. ``jnp.asarray`` on a host
      table) is constant placement, not a runtime host round-trip; the
      census still counts it, the transfer rule must not.
    """

    def __init__(self) -> None:
        self.census: Dict[str, int] = {}
        self.eqn_sig: List[Tuple[str, str]] = []
        self.big_outs: List[Tuple[str, Tuple[int, ...]]] = []
        self.converts: List[Tuple[str, str]] = []
        self.runtime_puts: int = 0


def _aval_str(v) -> str:
    aval = v.aval
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    return f"{dtype}[{','.join(str(s) for s in shape)}]"


def _walk(jaxpr, out: Survey) -> None:
    consts = set(map(id, getattr(jaxpr, "constvars", ())))
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out.census[name] = out.census.get(name, 0) + 1
        if name == "device_put":
            staged = all(hasattr(v, "val") or id(v) in consts
                         for v in eqn.invars)
            if not staged:
                out.runtime_puts += 1
        for v in eqn.outvars:
            aval = v.aval
            out.eqn_sig.append((name, _aval_str(v)))
            shape = getattr(aval, "shape", ())
            if len(shape) >= 2:
                out.big_outs.append((name, tuple(int(s) for s in shape)))
        if name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.outvars[0].aval
            out.converts.append((str(getattr(src, "dtype", "?")),
                                 str(getattr(dst, "dtype", "?"))))
        for p in eqn.params.values():
            _walk_param(p, out)


def _walk_param(p, out: Survey) -> None:
    if hasattr(p, "jaxpr") and hasattr(p.jaxpr, "eqns"):   # ClosedJaxpr
        _walk(p.jaxpr, out)
    elif hasattr(p, "eqns"):                               # raw Jaxpr
        _walk(p, out)
    elif isinstance(p, (list, tuple)):
        for q in p:
            _walk_param(q, out)


def survey(closed_jaxpr) -> Survey:
    """One recursive walk over a ClosedJaxpr (subjaxprs included)."""
    out = Survey()
    _walk(closed_jaxpr.jaxpr, out)
    return out


def fingerprint(closed_jaxpr, sv: Optional[Survey] = None) -> Dict:
    """The canonical fingerprint document for one entrypoint."""
    sv = sv if sv is not None else survey(closed_jaxpr)
    in_avals = [_aval_str(v) for v in closed_jaxpr.jaxpr.invars]
    out_avals = [_aval_str(v) for v in closed_jaxpr.jaxpr.outvars]
    h = hashlib.sha256()
    for name, aval in sv.eqn_sig:
        h.update(name.encode())
        h.update(aval.encode())
    for a in in_avals + out_avals:
        h.update(a.encode())
    return {
        "census": dict(sorted(sv.census.items())),
        "in": in_avals,
        "out": out_avals,
        "hash": h.hexdigest()[:16],
    }


def diff_fingerprints(old: Dict, new: Dict) -> str:
    """Primitive-level diff between two fingerprints, human-readable."""
    lines: List[str] = []
    oc, nc = old.get("census", {}), new.get("census", {})
    for prim in sorted(set(oc) | set(nc)):
        a, b = oc.get(prim, 0), nc.get(prim, 0)
        if a != b:
            lines.append(f"    {prim}: {a} -> {b} ({b - a:+d})")
    for field in ("in", "out"):
        if old.get(field) != new.get(field):
            lines.append(f"    {field} avals: {old.get(field)} -> "
                         f"{new.get(field)}")
    if not lines and old.get("hash") != new.get("hash"):
        lines.append(
            "    same census, different eqn sequence/avals "
            f"(hash {old.get('hash')} -> {new.get('hash')})")
    return "\n".join(lines)


# -- golden artifact io -----------------------------------------------------

def default_golden_path() -> Optional[str]:
    """``tests/fixtures/graphs.json`` at the repo root, if resolvable.

    The package normally runs from a source checkout
    (``<root>/src/repro/analysis/graph/`` -> ``<root>``); an installed
    copy without the tests tree returns None and the CLI reports a note
    instead of drift findings.
    """
    here = os.path.abspath(__file__)
    root = here
    for _ in range(5):
        root = os.path.dirname(root)
    cand = os.path.join(root, "tests", "fixtures", "graphs.json")
    return cand if os.path.isdir(os.path.dirname(cand)) else None


def load_golden(path: str) -> Dict:
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    if doc.get("tool") != "graphcheck":
        raise ValueError(f"{path} is not a graphcheck golden file")
    return doc


def golden_for_version(doc: Optional[Dict],
                       version: Optional[str] = None) -> Optional[Dict]:
    """The committed fingerprints for the RUNNING jax version, if any."""
    if doc is None:
        return None
    version = version or jax.__version__
    return doc.get("goldens", {}).get(version)


def merge_golden(doc: Optional[Dict], fingerprints: Dict[str, Dict],
                 version: Optional[str] = None) -> Dict:
    """Merge freshly computed fingerprints under the running version's
    key, preserving every other version's entries byte-for-byte."""
    version = version or jax.__version__
    out = {"tool": "graphcheck", "version": 1,
           "goldens": dict((doc or {}).get("goldens", {}))}
    out["goldens"][version] = {k: fingerprints[k]
                               for k in sorted(fingerprints)}
    return out


def dump_golden(doc: Dict, path: str) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
