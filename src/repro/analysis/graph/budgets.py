"""GRC001 memory-budget declarations — the repo's peak-temp contracts.

One place declares, per registered entrypoint, the byte bound its
compiled program's ``memory_analysis().temp_size_in_bytes`` must stay
under at the canonical big shapes.  The analyzer (``rules.GRC001``) and
``tests/test_megakernel.py``'s regression gate both consume these —
the thresholds cannot drift between the two surfaces.

Budget semantics: every bound is an O(n·tile)-class formula of the big
shapes, NOT a measured-value-plus-slack pin.  The streaming engine
surfaces keep the PR-8 megakernel-gate form — a tenth of the block the
pre-streaming graph materialised ([n, k] for loss/cache, [n, chunk] for
the exact fallback) — so a revert to any materialised form overshoots
the budget by 10x and trips GRC001 unambiguously.  The fused drivers are
budgeted at their true working set: the O(n·width) PIC ring plus a
fixed number of O(n·k) cache/carry-class temporaries.
"""

from __future__ import annotations

from typing import Dict

from repro.core.engine import _EXACT_CHUNK

__all__ = ["budget_bytes", "budget_doc", "budget_names", "shape_for",
           "N_BIG", "D_BIG", "K_BIG", "ROWS_PREDICT", "ROWS_ASSIGN",
           "N_DRIVER", "D_DRIVER", "K_DRIVER", "WIDTH_DRIVER"]

# Canonical big shapes — the megakernel gate's scale (PR 8).
N_BIG, D_BIG, K_BIG = 200_000, 16, 256
# Serving closures: one 8k-row predict bucket, one 128k-row assign pass.
ROWS_PREDICT = 8192
ROWS_ASSIGN = 131_072
# Fused single-fit drivers: moderate n (compile-time bound), pic ring.
N_DRIVER, D_DRIVER, K_DRIVER = 20_000, 8, 4
WIDTH_DRIVER = 12 * 32          # 12 round-batches of B=32 columns

_F32 = 4

# name -> (formula over the shape dict, human-readable formula doc)
_BUDGETS = {
    # Streaming loss/cache: must hold NO [n, k] block — same tenth-of-
    # the-block bound the PR-8 gate hardcoded.
    "engine.total_loss": (
        lambda s: s["n"] * s["k"] * _F32 // 10,
        "n*k*4 // 10  (a tenth of the materialised [n, k] block)"),
    "engine.medoid_cache": (
        lambda s: s["n"] * s["k"] * _F32 // 10,
        "n*k*4 // 10  (a tenth of the materialised [n, k] block)"),
    # Exact fallbacks: must hold NO [n, chunk] scan temp.
    "engine.exact_build_means": (
        lambda s: s["n"] * _EXACT_CHUNK * _F32 // 10,
        "n*512*4 // 10  (a tenth of the pre-streaming scan temp)"),
    # Exact swap means: the PRODUCT is the [k, n] per-arm mean table, so
    # one product-size staging copy is legal; the bound adds a tenth of
    # the pre-streaming [n, chunk] scan temp, which a revert to the
    # materialised walk overshoots by ~2x.
    "engine.exact_swap_means": (
        lambda s: s["n"] * s["k"] * _F32
        + s["n"] * _EXACT_CHUNK * _F32 // 10,
        "n*k*4 + n*512*4 // 10  (one [k, n] product-size staging copy "
        "+ tenth of the pre-streaming scan temp)"),
    # Interpret-mode stream kernels: these budgets bound the pallas
    # EMULATOR envelope, not the on-chip tile story (interpret mode
    # holds full-extent grid buffers by construction — measured: one
    # [m, n] block for build, two for swap's paired moment streams, one
    # [n, k] for top2).  The contract is still load-bearing: an extra
    # full-extent buffer smuggled into a kernel (a second g-matrix, an
    # un-fused square) adds a whole block and trips the 1.5x bound.
    "kernels.stream_build_g_stats": (
        lambda s: s["m"] * s["n"] * _F32 * 3 // 2,
        "m*n*4*3/2  (1.5x the interpret-mode [m, n] grid buffer)"),
    "kernels.stream_swap_g_stats": (
        lambda s: s["m"] * s["n"] * _F32 * 5 // 2,
        "m*n*4*5/2  (2.5x the [m, n] grid buffer: swap holds paired "
        "moment streams)"),
    "kernels.stream_top2": (
        lambda s: s["n"] * s["k"] * _F32 * 3 // 2,
        "n*k*4*3/2  (1.5x the interpret-mode [n, k] grid buffer)"),
    # Serving closures.  predict RETURNS the [rows, k] block (that block
    # is the product): temps around it stay under one extra block.
    "api.get_predict_fn": (
        lambda s: s["rows"] * s["k"] * _F32 * 2,
        "rows*k*4*2  (the returned block + one temp copy ceiling)"),
    "api.get_assign_fn": (
        lambda s: s["rows"] * s["k"] * _F32 // 10,
        "rows*k*4 // 10  (a tenth of the never-materialised block)"),
    # Fused drivers (pic): ring + a bounded number of n-vectors/cache
    # blocks.  The dominant legal temps are the [n, width] ring update
    # and the [n, k]-class candidate stats; 4 rings' worth of slack
    # keeps the bound far under any [n, n] materialisation (which is
    # n/width ~ 52x one ring at driver shapes).
    "core._build_fused[pic]": (
        lambda s: 4 * s["n"] * s["width"] * _F32,
        "4*n*width*4  (PIC ring working set; [n, n] would be ~52x)"),
    "core._swap_iter[pic]": (
        lambda s: 4 * s["n"] * s["width"] * _F32
        + 4 * s["n"] * s["k"] * _F32,
        "4*n*width*4 + 4*n*k*4  (ring + carry/cache working set)"),
}

# The shape dict each budgeted entrypoint is lowered at (kept next to
# the formulas so test_megakernel and the analyzer agree on BOTH).
_SHAPES: Dict[str, Dict[str, int]] = {
    "engine.total_loss": {"n": N_BIG, "d": D_BIG, "k": K_BIG},
    "engine.medoid_cache": {"n": N_BIG, "d": D_BIG, "k": K_BIG},
    "engine.exact_build_means": {"n": N_BIG, "d": D_BIG},
    "engine.exact_swap_means": {"n": N_BIG, "d": D_BIG, "k": K_BIG},
    "kernels.stream_build_g_stats": {"m": 256, "n": N_BIG, "d": D_BIG},
    "kernels.stream_swap_g_stats": {"m": 256, "n": N_BIG, "d": D_BIG},
    "kernels.stream_top2": {"n": N_BIG, "d": D_BIG, "k": K_BIG},
    "api.get_predict_fn": {"rows": ROWS_PREDICT, "k": K_BIG, "d": D_BIG},
    "api.get_assign_fn": {"rows": ROWS_ASSIGN, "k": K_BIG, "d": D_BIG},
    "core._build_fused[pic]": {"n": N_DRIVER, "d": D_DRIVER,
                               "k": K_DRIVER, "width": WIDTH_DRIVER},
    "core._swap_iter[pic]": {"n": N_DRIVER, "d": D_DRIVER,
                             "k": K_DRIVER, "width": WIDTH_DRIVER},
}


def budget_names():
    """All declared budget keys."""
    return tuple(_BUDGETS)


def shape_for(name: str) -> Dict[str, int]:
    """The canonical big-shape point ``name`` is budgeted at."""
    return dict(_SHAPES[name])


def budget_bytes(name: str, **shape) -> int:
    """Evaluate the declared byte bound for ``name``.

    With no ``shape`` overrides the canonical big shapes apply; tests
    may evaluate the same formula at other shape points.
    """
    formula, _ = _BUDGETS[name]
    s = shape_for(name)
    s.update(shape)
    return int(formula(s))


def budget_doc(name: str) -> str:
    """The human-readable formula behind ``budget_bytes(name)``."""
    return _BUDGETS[name][1]
