"""tracecheck runtime guard — transfer-guard + dispatch-count harness.

The static rules police what the *source* may do; this module polices
what a *running fit* actually does:

* :func:`guarded` / :class:`FitGuard` run a fused ``BanditPAM.fit``
  under ``jax.transfer_guard("disallow")``, so any device↔host transfer
  outside the sanctioned points (``engine.host_read`` explicit reads and
  ``engine.host_stage`` staging spans) raises at the offending call.
* The dispatch ledger check promotes the benchmark assertion
  ``dispatches_by_phase == {"build": 1, "swap": iters}`` (one jit
  dispatch per phase iteration, counted by ``engine.counted_dispatch``)
  to a first-class test fixture.
* :func:`jit_cache_sizes` snapshots the module-level jitted drivers'
  trace-cache sizes so tests can assert a second fit retraces nothing.

Import note: this module imports jax and the core driver; the static
half of :mod:`repro.analysis` stays stdlib-only.  The pytest fixtures
at the bottom are defined only when pytest is importable, so shipping
code may import the harness without a test dependency.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax

from repro.core.engine import host_read, host_stage  # noqa: F401  (re-export)

__all__ = ["FitGuard", "expected_dispatches", "guarded",
           "jit_cache_sizes", "host_read", "host_stage"]


@contextlib.contextmanager
def guarded():
    """``jax.transfer_guard("disallow")`` as a reusable context: implicit
    transfers raise, explicit ``host_read``/``host_stage`` remain legal."""
    with jax.transfer_guard("disallow"):
        yield


def expected_dispatches(report, *, warm: bool = False) -> Dict[str, int]:
    """The one-dispatch-per-phase contract for a fused fit's report.

    BUILD is a single fused dispatch (absent on warm starts); SWAP costs
    one dispatch per iteration — ``n_swaps`` accepted moves plus the
    final rejecting iteration when the fit converged rather than hitting
    ``max_swaps``.
    """
    iters = report.n_swaps + (1 if report.converged else 0)
    exp = {"swap": iters}
    if not warm:
        exp["build"] = 1
    return exp


def jit_cache_sizes() -> Dict[str, int]:
    """Trace-cache sizes of the module-level jitted fused drivers."""
    from repro.core import banditpam as bp
    return {
        "_build_fused": bp._build_fused._cache_size(),
        "_swap_iter": bp._swap_iter_jit._cache_size(),
        "_build_batch": bp._build_batch._cache_size(),
        "_swap_batch": bp._swap_batch._cache_size(),
    }


class FitGuard:
    """Runs fits under the transfer guard and checks the dispatch ledger.

    ``fit()`` warms the jit caches with one unguarded fit (compilation
    legitimately stages constants host→device), then repeats the fit
    inside ``transfer_guard("disallow")`` and asserts

    * the guarded report's medoids/loss/ledger match the warm-up run
      bit-for-bit (the guard must not change the computation), and
    * ``dispatches_by_phase`` equals :func:`expected_dispatches`.
    """

    def __init__(self) -> None:
        self.last_report = None

    def fit(self, est, data, *, warm_start=None, warmup: bool = True,
            check_dispatches: bool = True,
            check_retrace: bool = True) -> "object":
        if not getattr(est, "fused", True):
            raise ValueError(
                "FitGuard covers the fused driver; the stepped baseline "
                "syncs per sub-step by design and is exempt")
        baseline = None
        if warmup:
            baseline = est.fit(data, warm_start=warm_start)
        before = jit_cache_sizes() if (warmup and check_retrace) else None
        with guarded():
            report = est.fit(data, warm_start=warm_start)
        if before is not None:
            after = jit_cache_sizes()
            assert after == before, (
                f"guarded fit retraced a fused driver: {before} -> {after}")
        if baseline is not None:
            assert report.medoids.tolist() == baseline.medoids.tolist(), (
                "transfer guard changed the fit result (medoids)")
            assert report.loss == baseline.loss, (
                "transfer guard changed the fit result (loss)")
            assert report.evals_by_phase == baseline.evals_by_phase, (
                "transfer guard changed the eval ledger")
        if check_dispatches:
            exp = expected_dispatches(report, warm=warm_start is not None)
            assert report.dispatches_by_phase == exp, (
                f"dispatch ledger {report.dispatches_by_phase} != "
                f"one-dispatch-per-phase contract {exp}")
        self.last_report = report
        return report

    def fit_batch(self, est, datasets, *, seeds=None, warmup: bool = True,
                  check_dispatches: bool = True,
                  check_retrace: bool = True) -> "object":
        """The batched twin of :meth:`fit` for ``est.fit_batch``.

        Same discipline: one unguarded warm-up batch (compilation stages
        constants), then the identical batch under
        ``transfer_guard("disallow")``, asserting

        * zero retraces of the module-level batched drivers,
        * every per-fit report bit-matches the warm-up run (medoids,
          loss, eval ledger), and
        * the batch-level dispatch ledger is exactly
          ``{"build": 1, "swap": 1}`` — one jit per phase regardless of
          B, the whole point of the batched engine.
        """
        baseline = None
        if warmup:
            baseline = est.fit_batch(datasets, seeds)
        before = jit_cache_sizes() if (warmup and check_retrace) else None
        with guarded():
            batch = est.fit_batch(datasets, seeds)
        if before is not None:
            after = jit_cache_sizes()
            assert after == before, (
                f"guarded fit_batch retraced a fused driver: "
                f"{before} -> {after}")
        if baseline is not None:
            for i, (rep, base) in enumerate(zip(batch, baseline)):
                assert rep.medoids.tolist() == base.medoids.tolist(), (
                    f"transfer guard changed fit {i} (medoids)")
                assert rep.loss == base.loss, (
                    f"transfer guard changed fit {i} (loss)")
                assert rep.evals_by_phase == base.evals_by_phase, (
                    f"transfer guard changed fit {i}'s eval ledger")
        if check_dispatches:
            exp = {"build": 1, "swap": 1}
            assert batch.dispatches_by_phase == exp, (
                f"batch dispatch ledger {batch.dispatches_by_phase} != "
                f"one-jit-per-phase contract {exp}")
        self.last_report = batch
        return batch


try:  # pragma: no cover - exercised via pytest, absent in production
    import pytest
except ImportError:  # pragma: no cover
    pytest = None

if pytest is not None:
    @pytest.fixture
    def fit_guard() -> FitGuard:
        """Transfer-guard + dispatch-ledger harness for fused fits."""
        return FitGuard()

    @pytest.fixture
    def trace_guard():
        """Bare ``jax.transfer_guard("disallow")`` context factory."""
        return guarded
