"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8, head_dim 256)
d_ff=15360 vocab=262144; 5:1 local(1024-window):global, qk-norm, 128k ctx.
[hf:google/gemma-3-12b-pt]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, d_ff=15360, vocab=262144, head_dim=256,
    layer_pattern=("local",) * 5 + ("global",), window=1024, qk_norm=True,
    rope_theta=1_000_000.0,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, window=16)
