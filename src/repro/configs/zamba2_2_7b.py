"""zamba2-2.7b [hybrid]: 54L d_model=2560 Mamba-2 backbone (ssm_state=64)
+ ONE weight-shared attention block (32H, kv=32) invoked every 6 layers on
concat[h, x_embed].  [arXiv:2411.15242]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80,
    layer_pattern=("mamba2",) * 5 + ("mamba2+shared_attn",),
    ssm_state=64, ssm_head_dim=64, d_inner=5120,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=8, ssm_head_dim=16, d_inner=128)
