"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
decoder-only over EnCodec tokens, vocab=2048 x 4 codebooks; frontend STUB
(delay-pattern interleaving handled outside; input is [B, L, 4] codes).
[arXiv:2306.05284]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048, head_dim=64,
    layer_pattern=("global",), frontend="audio_stub", n_codebooks=4,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64, n_codebooks=2)
