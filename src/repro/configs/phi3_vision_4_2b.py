"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend STUB (input_specs provides
precomputed patch embeddings).  [hf:microsoft/Phi-3-vision-128k-instruct]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064, head_dim=96,
    layer_pattern=("global",), frontend="vision_stub", n_patches=576,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, n_patches=8)
