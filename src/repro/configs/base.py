"""Architecture + input-shape configuration (the assigned 40-cell matrix).

Every assigned architecture gets one module defining ``CONFIG`` with the
exact published numbers, plus ``reduced()`` — a same-family shrink for CPU
smoke tests.  ``SHAPES`` defines the four input-shape cells; helpers below
say which (arch x shape) cells are runnable (long_500k requires
sub-quadratic attention state, docs/design.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- attention pattern: repeating unit of layer kinds ---
    #   "global" | "local" (sliding window) | "chunked" (llama4 iRoPE) |
    #   "mamba1" | "mamba2" | "mamba2+shared_attn"
    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 1024
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False      # arctic: dense MLP in parallel
    shared_expert: bool = False           # llama4: always-on shared expert
    capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: Optional[int] = None         # default 2*d_model
    dt_rank: Optional[int] = None         # default d_model//16 (mamba1)
    ssm_head_dim: int = 64                # mamba2
    # --- frontend stubs ---
    frontend: str = "none"                # none | vision_stub | audio_stub
    n_patches: int = 576                  # vision_stub prefix length
    n_codebooks: int = 4                  # audio_stub codebooks
    # --- training knobs ---
    moment_dtype: str = "float32"         # "bfloat16" for the 480B config
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def di(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    def pattern_for_all_layers(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.n_layers]

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.layer_pattern) == 0, self.name
        return self.n_layers // len(self.layer_pattern)

    def param_count(self) -> Dict[str, float]:
        """Analytic parameter counts (total & active) for MODEL_FLOPS."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.frontend == "audio_stub":
            emb = self.n_codebooks * v * d + self.n_codebooks * v * d
        per_attn = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        per_mlp = 3 * d * ff
        total = active = emb
        for kind in self.pattern_for_all_layers():
            if kind.startswith("mamba"):
                di, st = self.di, self.ssm_state
                if kind.startswith("mamba2"):
                    nh = di // self.ssm_head_dim
                    m = d * (2 * di + 2 * st * (di // self.ssm_head_dim if False else 1) * 0)  # see below
                    # mamba2: in_proj d->(2*di + 2*n_groups*st + nh), conv, out_proj
                    m = d * (2 * di + 2 * st + nh) + di * d + 3 * di
                else:
                    m = d * 2 * di + di * (self.dtr + 2 * st) + self.dtr * di \
                        + di * st + di * d + self.ssm_conv * di
                total += m
                active += m
                if "shared_attn" in kind:
                    pass  # counted once below
            else:
                total += per_attn
                active += per_attn
                if self.n_experts > 0:
                    total += self.n_experts * per_mlp + d * self.n_experts
                    active += self.top_k * per_mlp + d * self.n_experts
                    if self.moe_dense_residual or self.shared_expert:
                        total += per_mlp
                        active += per_mlp
                else:
                    total += per_mlp
                    active += per_mlp
        if any("shared_attn" in k for k in self.pattern_for_all_layers()):
            total += per_attn + per_mlp + 2 * d * d     # one shared block + concat proj
            n_calls = sum("shared_attn" in k for k in self.pattern_for_all_layers())
            active += n_calls * (per_attn + per_mlp + 2 * d * d)
        return {"total": float(total), "active": float(active)}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode
    microbatches: int = 1


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", microbatches=4),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "falcon_mamba_7b", "arctic_480b", "llama4_scout_17b", "gemma3_12b",
    "mistral_nemo_12b", "granite_8b", "qwen3_1_7b", "phi3_vision_4_2b",
    "zamba2_2_7b", "musicgen_large",
)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.reduced()


def supports_long_context(cfg: ArchConfig) -> bool:
    """long_500k runs only for archs whose state is sub-quadratic
    (SSM / hybrid / windowed-or-chunked attention)."""
    kinds = set(cfg.pattern_for_all_layers())
    full_attn = [k for k in kinds if k == "global"]
    sub_quadratic = all(k != "global" for k in kinds) or \
        (len(full_attn) > 0 and any(k in ("local", "chunked") or k.startswith("mamba")
                                    for k in kinds))
    # pure full-attention stacks are excluded
    return kinds != {"global"}


def cells(arch_id: str):
    """The runnable shape cells for an arch (skips noted in docs/design.md)."""
    cfg = get_config(arch_id)
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not supports_long_context(cfg):
            out.append((s.name, "skipped (pure full attention)"))
        else:
            out.append((s.name, "run"))
    return out


def reduce_cfg(cfg: ArchConfig, **overrides) -> ArchConfig:
    return dataclasses.replace(cfg, **overrides)
