"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base]

bf16 AdamW moments: fp32 states for 479B params exceed a 512-chip v5e
pod-pair's HBM (docs/design.md §Memory-fit)."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, head_dim=128,
    layer_pattern=("global",), n_experts=128, top_k=2,
    moe_dense_residual=True, moment_dtype="bfloat16",
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256, n_experts=4, top_k=2, moment_dtype="float32")
