"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free Mamba-1,
ssm_state=16, vocab=65024.  [arXiv:2410.05355]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024,
    layer_pattern=("mamba1",), ssm_state=16, ssm_conv=4, d_inner=8192,
    tie_embeddings=True,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, d_inner=128, vocab=256,
        ssm_state=4, dt_rank=8)
