"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert; 3:1 chunked-local
(iRoPE) : global attention, chunk 8192.  [hf:meta-llama/Llama-4-Scout-17B-16E]"""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    layer_pattern=("chunked", "chunked", "chunked", "global"), window=8192,
    n_experts=16, top_k=1, shared_expert=True, rope_theta=500_000.0,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, n_experts=4, top_k=1, window=32)
