from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, cells,
                   get_config, get_reduced, supports_long_context)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "cells",
           "get_config", "get_reduced", "supports_long_context"]
