"""Distributed-engine sweep: ``banditpam_dist`` on a simulated
multi-device mesh vs the single-device solver at fixed (n, k).

The device-count flag must be set before jax initialises, so the
multi-device half runs in a subprocess; results come back as JSON and
are emitted as the usual CSV rows (and serialised to
``BENCH_distributed.json`` by ``benchmarks/run.py --json``).

Knobs: ``REPRO_BENCH_DEVICES`` (simulated CPU devices, default 8),
``REPRO_BENCH_PALLAS=1`` adds the interpret-mode Pallas backend row
off-accelerator (same convention as ``core_bench``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import FULL, emit

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + sys.argv[1])
    n, k = int(sys.argv[2]), int(sys.argv[3])
    backends = sys.argv[4].split(",")
    from repro.api import KMedoids
    from repro.core import datasets
    from repro.core.distributed import default_mesh

    data = datasets.make("mnist_like", n, seed=0)
    mesh = default_mesh()
    rows = {}
    for solver in ("banditpam", "banditpam_dist"):
        for backend in backends:
            params = ({"mesh": mesh} if solver == "banditpam_dist"
                      else {"baseline": "leader"})
            t0 = time.perf_counter()
            est = KMedoids(k, solver=solver, metric="l2", seed=0,
                           backend=backend, **params).fit(data)
            wall = time.perf_counter() - t0
            r = est.report_
            rows[f"{solver}[{backend}]"] = {
                "loss": float(r.loss),
                "wall_s": round(wall, 3),
                "wall_by_phase": {p: round(v, 4)
                                  for p, v in r.wall_by_phase.items()},
                "ledger": r.ledger(),
            }
    print(json.dumps(rows))
""")


def sweep(n=None, k=5, devices=None, backends=None):
    if n is None:
        n = 1024 if FULL else 512
    if devices is None:
        devices = int(os.environ.get("REPRO_BENCH_DEVICES", "8"))
    if backends is None:
        backends = ["jnp"]
        if os.environ.get("REPRO_BENCH_PALLAS", "0") == "1":
            backends.append("pallas")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, str(devices), str(n), str(k),
         ",".join(backends)],
        capture_output=True, text=True, timeout=1800,
        env=dict(os.environ, PYTHONPATH="src"))
    if out.returncode != 0:
        raise RuntimeError(f"distributed bench child failed:\n"
                           f"{out.stderr[-2000:]}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    for name, row in rows.items():
        emit(f"distributed_{name}_n{n}_dev{devices}", row["wall_s"] * 1e6,
             f"loss={row['loss']:.4f};fresh={row['ledger']['fresh']}")
    return {"bench": "distributed", "n": int(n), "k": int(k),
            "devices": int(devices), "rows": rows}


def write_json(path="BENCH_distributed.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("distributed_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
