"""Distributed-engine sweep: ``banditpam_dist`` on a simulated
multi-device mesh vs the single-device solver at fixed (n, k), including
the ``reuse="pic"`` sharded-cache row.

Per row it records the loss, wall clock, the fresh/cached ledger, the
cached fraction, and the driver's per-phase jit dispatch counts — and
ASSERTS that the fused sharded BUILD issued ONE dispatch for the whole
phase (not one per selection): the regression guard for the
fori_loop-fused BUILD, enforced wherever the bench runs (CI uploads the
JSON as an artifact).

The device-count flag must be set before jax initialises, so the
multi-device half runs in a subprocess; results come back as JSON and
are emitted as the usual CSV rows (and serialised to
``BENCH_distributed.json`` by ``benchmarks/run.py --json``).

Knobs: ``REPRO_BENCH_DEVICES`` (simulated CPU devices, default 8),
``REPRO_BENCH_PALLAS=1`` adds the interpret-mode Pallas backend row
off-accelerator (same convention as ``core_bench``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import FULL, emit

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + sys.argv[1])
    n, k = int(sys.argv[2]), int(sys.argv[3])
    backends = sys.argv[4].split(",")
    from repro.api import KMedoids
    from repro.core import datasets
    from repro.core.distributed import default_mesh

    data = datasets.make("mnist_like", n, seed=0)
    mesh = default_mesh()
    rows = {}
    cases = [("banditpam", {"baseline": "leader"}),
             ("banditpam_dist", {"mesh": mesh}),
             ("banditpam_dist[pic]", {"mesh": mesh, "reuse": "pic"})]
    for name, params in cases:
        solver = name.split("[")[0]
        for backend in backends:
            t0 = time.perf_counter()
            est = KMedoids(k, solver=solver, metric="l2", seed=0,
                           backend=backend, **params).fit(data)
            wall = time.perf_counter() - t0
            r = est.report_
            led = r.ledger()
            total = led["fresh"] + led["cached"]
            rows[f"{name}[{backend}]"] = {
                "loss": float(r.loss),
                "wall_s": round(wall, 3),
                "wall_by_phase": {p: round(v, 4)
                                  for p, v in r.wall_by_phase.items()},
                "ledger": led,
                "cached_fraction": round(led["cached"] / total, 4),
                "dispatches_by_phase": dict(r.dispatches_by_phase),
                "n_swaps": int(r.n_swaps),
                "converged": bool(r.converged),
            }
    print(json.dumps(rows))
""")


def _assert_single_dispatch_build(rows: dict) -> None:
    """CI guard: the fused sharded BUILD is one jit dispatch per phase."""
    for name, row in rows.items():
        if not name.startswith("banditpam_dist"):
            continue
        d = row["dispatches_by_phase"]
        if d.get("build") != 1:
            raise AssertionError(
                f"{name}: sharded BUILD issued {d.get('build')} dispatches "
                f"— the fori_loop fusion regressed (expected 1 per phase)")
        # One fused step per iteration: every accepted swap plus — only
        # when the fit converged — the final non-improving check.  A fit
        # that exhausts max_swaps ends on an accepted swap (no +1).
        want_swap = row["n_swaps"] + (1 if row["converged"] else 0)
        if d.get("swap") != want_swap:
            raise AssertionError(
                f"{name}: sharded SWAP issued {d.get('swap')} dispatches "
                f"for {row['n_swaps']} accepted swaps (expected "
                f"{want_swap} fused steps)")


def sweep(n=None, k=5, devices=None, backends=None):
    if n is None:
        n = 1024 if FULL else 512
    if devices is None:
        devices = int(os.environ.get("REPRO_BENCH_DEVICES", "8"))
    if backends is None:
        backends = ["jnp"]
        if os.environ.get("REPRO_BENCH_PALLAS", "0") == "1":
            backends.append("pallas")
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, str(devices), str(n), str(k),
         ",".join(backends)],
        capture_output=True, text=True, timeout=1800,
        env=dict(os.environ, PYTHONPATH="src"))
    if out.returncode != 0:
        raise RuntimeError(f"distributed bench child failed:\n"
                           f"{out.stderr[-2000:]}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    _assert_single_dispatch_build(rows)
    for name, row in rows.items():
        emit(f"distributed_{name}_n{n}_dev{devices}", row["wall_s"] * 1e6,
             f"loss={row['loss']:.4f};fresh={row['ledger']['fresh']};"
             f"cached_frac={row['cached_fraction']};"
             f"build_dispatches={row['dispatches_by_phase'].get('build')}")
    return {"bench": "distributed", "n": int(n), "k": int(k),
            "devices": int(devices), "rows": rows}


def write_json(path="BENCH_distributed.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("distributed_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
