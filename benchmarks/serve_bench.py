"""Serving-layer benchmark: predict latency, refit-behind-traffic
throughput, and the warm-vs-cold refit ledger.

Three numbers the streaming ``MedoidService`` (ISSUE 7) stands on:

* **p50/p99 predict latency** — per-request wall times through the
  cached jitted closure (``repro.api.predict.get_predict_fn``): after
  the first bucket compile, every request is one dispatch; the p99/p50
  gap is the retrace test.
* **refit-behind-traffic throughput** — rows/s ingested over a drifted
  stream INCLUDING every drift-triggered warm refit the monitor fires;
  the cost of staying fitted, not just of serving.
* **warm vs cold refit ledger** — the same refit sample + seed solved
  both ways; the JSON carries both ledgers and the sanity gate asserts
  the warm refit actually reused work (nonzero cached fraction) and
  skipped BUILD (zero build evals).

``benchmarks/run.py --json`` serialises this as ``BENCH_serve.json``
(a CI artifact next to ``BENCH_multifit.json``).
"""
from __future__ import annotations

import json
import os
import statistics

import jax
import numpy as np

from repro.core import datasets
from repro.serve import MedoidService

from .common import FULL, emit, timed

K = 5
N_FIT = 2000 if FULL else 600
N_STREAM = 2000 if FULL else 800
REQ_ROWS = 256                   # rows per predict request
N_REQ = 200 if FULL else 60      # timed predict requests
CHUNK = 120                      # ingest chunk (rows)
D = 64


def _quantile(xs, q):
    return float(np.quantile(np.asarray(xs, np.float64), q))


def sweep(n_fit=N_FIT, n_stream=N_STREAM, k=K, seed=0):
    X = datasets.make("mnist_like", n_fit, seed=seed, d=D)
    svc = MedoidService(k, "l2", backend="jnp",
                        reservoir_size=min(512, n_fit),
                        drift_threshold=0.2, drift_window=200,
                        request_chunk=REQ_ROWS, seed=seed)
    _, fit_wall = timed(lambda: svc.fit(X))

    # -- predict latency over fixed-size requests (closure pre-warmed by
    # fit's reservoir seeding; first timed request is steady-state)
    queries = datasets.make("mnist_like", REQ_ROWS * 4, seed=seed + 1, d=D)
    walls = []
    for i in range(N_REQ):
        lo = (i * REQ_ROWS) % (REQ_ROWS * 3)
        _, w = timed(svc.predict, queries[lo:lo + REQ_ROWS])
        walls.append(w)
    p50, p99 = _quantile(walls, 0.5), _quantile(walls, 0.99)
    emit("serve_predict_p50", p50 * 1e6,
         f"p99_us={p99 * 1e6:.1f};rows={REQ_ROWS}")

    # -- refit-behind-traffic: drifted stream, refits included in the wall
    stream = datasets.make("mnist_like", n_stream, seed=seed + 2,
                           d=D) + np.float32(0.5)
    n_refits = 0
    refit_walls = []

    def _drain():
        nonlocal n_refits
        for lo in range(0, n_stream, CHUNK):
            r, w = timed(svc.ingest, stream[lo:lo + CHUNK])
            if r.refit is not None:
                n_refits += 1
                refit_walls.append(w)

    _, ingest_wall = timed(_drain)
    ingest_rows_per_s = n_stream / ingest_wall
    emit("serve_ingest_rows_per_s", ingest_wall / n_stream * 1e6,
         f"rows_per_s={ingest_rows_per_s:.0f};refits={n_refits}")

    # -- warm vs cold ledger on the same refit sample + seed
    warm, cold = svc.refit_report_pair()
    wl, cl = warm.ledger(), cold.ledger()
    warm_cached_fraction = wl["cached"] / max(1, wl["cached"] + wl["fresh"])
    # sanity gates: the warm path must actually be warm
    assert wl["cached"] > 0, "warm refit reported zero cached evals"
    assert warm.evals_by_phase["build"] == 0, "warm refit ran BUILD"
    emit("serve_refit_warm_vs_cold", 0.0,
         f"warm_fresh={wl['fresh']};cold_fresh={cl['fresh']};"
         f"warm_cached_fraction={warm_cached_fraction:.3f}")

    return {
        "bench": "serve", "n_fit": int(n_fit), "n_stream": int(n_stream),
        "k": int(k), "d": int(D), "metric": "l2",
        "device": jax.default_backend(), "cpu_count": os.cpu_count(),
        "fit_wall_s": round(fit_wall, 4),
        "predict": {
            "request_rows": REQ_ROWS, "n_requests": N_REQ,
            "p50_ms": round(p50 * 1e3, 4), "p99_ms": round(p99 * 1e3, 4),
            "rows_per_s": round(REQ_ROWS / p50, 1),
        },
        "ingest": {
            "chunk_rows": CHUNK, "wall_s": round(ingest_wall, 4),
            "rows_per_s": round(ingest_rows_per_s, 1),
            "n_refits": int(n_refits),
            "refit_wall_s_median": round(
                statistics.median(refit_walls), 4) if refit_walls else None,
        },
        "refit_ledger": {
            "warm": {"loss": round(float(warm.loss), 4),
                     "fresh": int(wl["fresh"]), "cached": int(wl["cached"]),
                     "n_swaps": int(warm.n_swaps)},
            "cold": {"loss": round(float(cold.loss), 4),
                     "fresh": int(cl["fresh"]), "cached": int(cl["cached"]),
                     "n_swaps": int(cold.n_swaps)},
            "warm_cached_fraction": round(warm_cached_fraction, 4),
            "warm_fresh_savings": round(
                1.0 - wl["fresh"] / max(1, cl["fresh"]), 4),
        },
        "service_stats": svc.stats(),
    }


def write_json(path="BENCH_serve.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("serve_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
