"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Set REPRO_BENCH_FULL=1 for the
paper-scale grids (default: CPU-quick grids)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (kernels_bench, loss_quality, roofline, scaling_n,
                   sigma_adaptivity, violation_pca)
    print("name,us_per_call,derived")
    failed = []
    for mod in (loss_quality, scaling_n, sigma_adaptivity, violation_pca,
                kernels_bench, roofline):
        try:
            mod.run()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
