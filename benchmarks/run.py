"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  Set REPRO_BENCH_FULL=1 for the
paper-scale grids (default: CPU-quick grids).

``--json [PATH]`` runs only the machine-readable sweeps and writes them as
JSON: the facade solver sweep to PATH (default ``BENCH_solvers.json``,
loss + the fresh/cached distance-evaluation ledger per registered solver
at fixed (n, k)), the core-engine wall-clock sweep (per-solver ×
stats-backend × fused/stepped driver, median of >= 3 reps) to
``BENCH_core.json`` next to it, the sharded-engine sweep
(``banditpam_dist`` on simulated devices vs the single-device solver) to
``BENCH_distributed.json``, and the batched multi-fit throughput sweep
(``fit_batch`` vs the Python loop at B=64) to ``BENCH_multifit.json``,
and the serving-layer sweep (p50/p99 predict latency,
refit-behind-traffic throughput, warm-vs-cold refit ledger) to
``BENCH_serve.json``, and the compiled-graph cost census (flops/bytes
from ``cost_analysis`` + peak temp vs the GRC001 budget, per graphcheck
entrypoint) to ``BENCH_graphs.json``.
``--solver`` (repeatable) restricts the solver sweep to named solvers."""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main(argv=None) -> None:
    from repro.api import available_solvers

    from . import (core_bench, distributed_bench, graphs_bench,
                   kernels_bench, loss_quality, megakernel_bench,
                   multifit_bench, roofline, scaling_n, serve_bench,
                   sigma_adaptivity, solvers, violation_pca)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_solvers.json",
                    default=None, metavar="PATH",
                    help="write the solver sweep to PATH as JSON and exit")
    ap.add_argument("--solver", action="append", choices=available_solvers(),
                    help="restrict the solver sweep (repeatable; default: "
                         "every registered solver)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.json is not None:
        outdir = os.path.dirname(args.json) or "."
        solvers.write_json(args.json, solvers=args.solver)
        core_bench.write_json(os.path.join(outdir, "BENCH_core.json"))
        distributed_bench.write_json(
            os.path.join(outdir, "BENCH_distributed.json"))
        multifit_bench.write_json(
            os.path.join(outdir, "BENCH_multifit.json"))
        serve_bench.write_json(os.path.join(outdir, "BENCH_serve.json"))
        megakernel_bench.write_json(
            os.path.join(outdir, "BENCH_megakernel.json"))
        graphs_bench.write_json(os.path.join(outdir, "BENCH_graphs.json"))
        return
    failed = []
    for mod in (loss_quality, scaling_n, sigma_adaptivity, violation_pca,
                solvers, core_bench, distributed_bench, multifit_bench,
                serve_bench, kernels_bench, megakernel_bench, graphs_bench,
                roofline):
        try:
            if mod is solvers:
                mod.sweep(solvers=args.solver)
            else:
                mod.run()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
