"""Compiled-graph cost census (docs/design.md #10) → BENCH_graphs.json.

For every budgeted graphcheck entrypoint, lower + compile at the
declared big shapes and record what XLA itself reports:

* ``cost_analysis`` — flops and bytes accessed (the analytic roofline
  inputs, straight from the compiled executable rather than the
  hand-derived formulas in ``benchmarks.roofline``);
* ``memory_analysis`` — the peak temp the GRC001 budget bounds, next to
  the bound itself and the headroom ratio.

The artifact makes budget drift visible in CI history: a PR that eats
headroom shows up as a ratio step long before it trips the analyzer.
"""
from __future__ import annotations

import json

from .common import emit


def _cost_totals(compiled):
    """Fold ``cost_analysis()`` to {flops, bytes}.  On jax 0.4.x the
    call returns a LIST of per-computation dicts; newer jax returns the
    dict directly."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, dict):
        ca = [ca]
    out = {"flops": 0.0, "bytes": 0.0}
    for entry in ca:
        out["flops"] += float(entry.get("flops", 0.0))
        out["bytes"] += float(entry.get("bytes accessed", 0.0))
    return out


def collect():
    from repro.analysis.graph import budgets
    from repro.analysis.graph.entrypoints import registry

    rows = []
    for spec in registry():
        if spec.budget is None:
            continue
        fn, args, kw = spec.build_big()
        compiled = fn.lower(*args, **kw).compile()
        ma = compiled.memory_analysis()
        temp = int(ma.temp_size_in_bytes) if ma is not None and \
            hasattr(ma, "temp_size_in_bytes") else None
        bound = budgets.budget_bytes(spec.budget)
        row = {
            "entrypoint": spec.name,
            "shape": budgets.shape_for(spec.budget),
            "budget_bytes": bound,
            "budget_doc": budgets.budget_doc(spec.budget),
            "temp_bytes": temp,
            "headroom": round(temp / bound, 4) if temp is not None
            else None,
            **_cost_totals(compiled),
        }
        rows.append(row)
    return rows


def write_json(path: str) -> None:
    import jax
    doc = {"bench": "graphs", "jax": jax.__version__,
           "entrypoints": collect()}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


def run() -> None:
    for row in collect():
        emit(f"graphs/{row['entrypoint']}", 0.0,
             f"temp={row['temp_bytes']} budget={row['budget_bytes']} "
             f"headroom={row['headroom']}")


if __name__ == "__main__":
    run()
