"""Uniform solver sweep at fixed (n, k) through the ``repro.api`` facade:
loss + the fresh/cached distance-evaluation ledger for every registered
solver.  ``benchmarks/run.py --json`` serialises the sweep to
``BENCH_solvers.json`` — the machine-readable perf trajectory."""
from __future__ import annotations

import json

from repro.api import KMedoids, available_solvers, default_params

from repro.core import datasets

from .common import BENCH_EXTRA, FULL, emit, timed


def sweep(n=None, k=5, metric="l2", solvers=None):
    if n is None:
        n = 2000 if FULL else 600
    data = datasets.make("mnist_like", n, seed=0)
    rows = {}
    for s in solvers or available_solvers():
        params = {**default_params(s), **BENCH_EXTRA.get(s, {})}
        est, wall = timed(lambda s=s, params=params:
                          KMedoids(k, solver=s, metric=metric, seed=0,
                                   **params).fit(data))
        r = est.report_
        rows[s] = {
            "loss": float(r.loss),
            "n_swaps": int(r.n_swaps),
            "converged": bool(r.converged),
            "wall_s": round(wall, 3),
            "ledger": r.ledger(),
        }
        emit(f"solvers_{s}_n{n}", wall * 1e6,
             f"loss={r.loss:.4f};fresh={r.distance_evals};"
             f"cached={r.cached_evals}")
    return {"bench": "solvers", "n": int(n), "k": int(k), "metric": metric,
            "solvers": rows}


def write_json(path="BENCH_solvers.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("solvers_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
