"""Shared benchmark helpers: timing, CSV emission, slope fits."""
from __future__ import annotations

import math
import os
import time
from typing import Callable, List


FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

# Bench-only solver overrides on top of repro.api.default_params (shared by
# every benchmark that sweeps solvers): CLARANS' default neighbor budget is
# n-scaled and would dwarf every other solver at bench sizes.
BENCH_EXTRA = {
    "clarans": dict(max_neighbors=150),
}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def loglog_slope(xs: List[float], ys: List[float]) -> float:
    lx = [math.log(x) for x in xs]
    ly = [math.log(max(y, 1e-12)) for y in ys]
    n = len(xs)
    mx, my = sum(lx) / n, sum(ly) / n
    num = sum((a - mx) * (b - my) for a, b in zip(lx, ly))
    den = sum((a - mx) ** 2 for a in lx)
    return num / den


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)
