"""Paper Appendix Fig. 1 + §3.2: the value of per-arm, per-step sigma_x.

(a) Reports the sigma_x distribution (min/median/max) at each BUILD step —
    the paper's boxplot shows the median dropping sharply after the first
    assignment.
(b) Ablation: per-arm sigma (paper) vs one global sigma (fixed to the
    first batch's pooled std) — distance evaluations to finish BUILD."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datasets
from repro.core.banditpam import _build_g, _build_step_jit
from repro.core.distances import get_metric

from .common import FULL, emit


def sigma_distribution(n=2000, k=5, seed=0):
    data = jnp.asarray(datasets.mnist_like(n, seed=seed))
    dist = get_metric("l2")
    dnear = jnp.full((n,), jnp.inf)
    med_mask = jnp.zeros((n,), bool)
    key = jax.random.PRNGKey(seed)
    rows = []
    for step in range(k):
        # first-batch sigma estimate for every arm (Eq. 11)
        key, sub = jax.random.split(key)
        ref = jax.random.randint(sub, (100,), 0, n)
        g = _build_g(dist(data, data[ref]), dnear[ref])
        sig = np.asarray(jnp.std(g, axis=1))
        rows.append((step, float(np.min(sig)), float(np.median(sig)),
                     float(np.max(sig))))
        emit(f"appfig1_sigma_step{step}", 0.0,
             f"min={rows[-1][1]:.4f};median={rows[-1][2]:.4f};max={rows[-1][3]:.4f}")
        sr = _build_step_jit(data, dnear, med_mask, sub, None, 0, None,
                             backend="jnp", metric="l2", batch_size=100,
                             delta=1.0 / (1000 * n), sampling="permutation",
                             baseline="none", mode="none", free_rounds=0)
        m = int(sr.best)
        med_mask = med_mask.at[m].set(True)
        dnear = jnp.minimum(dnear, dist(data[m][None], data)[0])
    return rows


def fixed_vs_adaptive_sigma(n=2000, k=5, seed=0):
    """Evals with per-arm sigma vs a single pooled sigma for all arms."""
    from repro.core.adaptive import adaptive_search
    data = jnp.asarray(datasets.mnist_like(n, seed=seed))
    dist = get_metric("l2")

    def run_mode(pooled: bool) -> int:
        dnear = jnp.full((n,), jnp.inf)
        med_mask = jnp.zeros((n,), bool)
        key = jax.random.PRNGKey(seed)
        total = 0
        for _ in range(k):
            key, sub = jax.random.split(key)

            def stats_fn(ref_idx, w, lead, rnd):
                g = _build_g(dist(data, data[ref_idx]), dnear[ref_idx]) * w
                s1, s2 = jnp.sum(g, 1), jnp.sum(g * g, 1)
                if pooled:  # replace per-arm batch stats with pooled ones
                    b = jnp.sum(w)
                    mu = jnp.sum(s1) / (n * b)
                    var = jnp.maximum(jnp.sum(s2) / (n * b) - mu * mu, 0.0)
                    s2 = (var + mu * mu) * b * jnp.ones_like(s2)
                    # keep s1 per-arm (means must stay per-arm); only the
                    # sigma estimate (from s2 - s1^2/b) becomes pooled
                    s2 = s1 * s1 / jnp.maximum(b, 1.0) + var * b
                return s1, s2, g @ g[lead]

            def exact_fn():
                return jnp.mean(_build_g(dist(data, data), dnear), 1)

            sr = adaptive_search(sub, stats_fn=stats_fn, exact_fn=exact_fn,
                                 n_arms=n, n_ref=n, batch_size=100,
                                 active_init=jnp.logical_not(med_mask))
            m = int(sr.best)
            med_mask = med_mask.at[m].set(True)
            dnear = jnp.minimum(dnear, dist(data[m][None], data)[0])
            total += int(sr.n_evals)
        return total

    per_arm = run_mode(False)
    pooled = run_mode(True)
    emit("appfig1_sigma_ablation", 0.0,
         f"per_arm_evals={per_arm};pooled_evals={pooled};"
         f"pooled_over_perarm_ratio={pooled/max(per_arm,1):.2f}")
    return per_arm, pooled


def swap_reuse_ablation(n=1500, k=5, seed=0):
    """Reuse-on/off axis: SWAP-phase fresh vs cached distance evaluations
    with the BanditPAM++ PIC engine enabled/disabled.  With reuse the σ/CI
    machinery starts each swap iteration from the carried moments, so later
    iterations typically resolve without sampling at all."""
    from repro.core import BanditPAM
    data = datasets.mnist_like(n, seed=seed)
    rows = {}
    for reuse in ("none", "pic"):
        b = BanditPAM(k, "l2", seed=seed, reuse=reuse).fit(data)
        fresh = b.evals_by_phase.get("swap", 0)
        cached = b.evals_by_phase.get("swap_cached", 0)
        rows[reuse] = (fresh, cached, b.n_swaps)
        emit(f"appfig1_swap_reuse_{reuse}", 0.0,
             f"swap_fresh={fresh};swap_cached={cached};swaps={b.n_swaps}")
    f_none, f_pic = rows["none"][0], max(rows["pic"][0], 1)
    emit("appfig1_swap_reuse_ratio", 0.0,
         f"fresh_none_over_pic={f_none / f_pic:.1f}x")
    return rows


def run():
    n = 4000 if FULL else 1500
    sigma_distribution(n=n)
    fixed_vs_adaptive_sigma(n=n)
    swap_reuse_ablation(n=n)


if __name__ == "__main__":
    run()
