"""Paper Figs. 1b / 2 / 3: distance evaluations (and wall time) per
iteration vs n, across datasets/metrics/k — the almost-linear-scaling
claim.  PAM/FastPAM1 references are exact: k*n^2 and n^2 per iteration.
Each mode is a (solver, params) pair driven through the ``repro.api``
facade."""
from __future__ import annotations

import numpy as np

from repro.api import KMedoids

from repro.core import datasets

from .common import FULL, emit, loglog_slope, timed

CASES = [
    # (figure, dataset, metric, k)
    ("fig2a_mnist_l2_k5", "mnist_like", "l2", 5),
    ("fig2b_mnist_l2_k10", "mnist_like", "l2", 10),
    ("fig3a_mnist_cosine_k5", "mnist_like", "cosine", 5),
    ("fig3b_scrna_l1_k5", "scrna_like", "l1", 5),
    ("fig1b_hoc4_tree_k2", "hoc4_like", "l1", 2),
]


def _modes(n: int):
    return {
        # paper-faithful §3.2: iid replacement sampling, raw CIs
        "paper": ("banditpam", dict(sampling="replacement", baseline="none")),
        # + App 2.2 permutation/FPC + leader control variate + warm cache
        # (cache scaled to n/4 so the upfront n*C warm block never
        #  dominates at small n — see EXPERIMENTS §Perf track 3 iter 4)
        "optimized": ("banditpam", dict(sampling="permutation",
                                        baseline="leader",
                                        cache_cols=min(1000, n // 4))),
        # + BanditPAM++ SWAP reuse: lazily-grown PIC distance cache and
        # carried per-arm statistics across swap iterations (reuse axis)
        "optimized_pic": ("banditpam_pp", dict(baseline="leader")),
    }


def run():
    sizes = [1000, 2000, 4000, 6000] if FULL else [500, 1000, 2000]
    out = {}
    for name, ds, metric, k in CASES:
        for mode in ("paper", "optimized", "optimized_pic"):
            evs, walls = [], []
            for n in sizes:
                solver, kw = _modes(n)[mode]
                data = datasets.make(ds, n, seed=7)
                est, wall = timed(
                    lambda k=k, solver=solver, metric=metric, kw=kw, data=data:
                    KMedoids(k, solver=solver, metric=metric, seed=0,
                             **kw).fit(data))
                b = est.report_
                iters = k + b.n_swaps + 1
                evs.append(b.distance_evals / iters)
                walls.append(wall / iters)
                emit(f"{name}_{mode}_n{n}", wall * 1e6,
                     f"evals_per_iter={evs[-1]:.0f};n2={n*n};swaps={b.n_swaps};"
                     f"swap_fresh={b.evals_by_phase.get('swap', 0)};"
                     f"swap_cached={b.evals_by_phase.get('swap_cached', 0)}")
            slope = loglog_slope(sizes, evs)
            red = (sizes[-1] ** 2) / evs[-1]
            emit(f"{name}_{mode}_slope", float(np.mean(walls)) * 1e6,
                 f"slope={slope:.3f};reduction_vs_fastpam1={red:.1f}x")
            out[f"{name}_{mode}"] = slope
    return out


if __name__ == "__main__":
    run()
