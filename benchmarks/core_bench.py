"""Core-engine wall-clock sweep: per-solver × stats-backend × driver,
median of >= 3 reps at fixed (n, k), alongside the algorithmic ledger.

``benchmarks/run.py --json`` serialises this as ``BENCH_core.json`` (a CI
artifact next to ``BENCH_solvers.json``), making the engine's perf
trajectory measurable in-repo.  Each bandit row carries per-phase
wall-clock medians (``FitReport.wall_by_phase``); the ``stepped`` driver
rows are the pre-refactor host-orchestrated baseline (one dispatch + one
host sync per sub-step, same math), so the fused/stepped delta IS the
device-residency win measured in the same run environment.

On CPU the Pallas backend runs in interpret mode (orders of magnitude
slow), so the backend axis defaults to ``("jnp",)`` off-accelerator;
set REPRO_BENCH_PALLAS=1 to force the kernel rows.
"""
from __future__ import annotations

import json
import os
import statistics

import jax

from repro.api import KMedoids, default_params

from repro.core import datasets

from .common import FULL, emit, timed

# The engine rows: the paper solver and the reuse engine, fused vs stepped.
SOLVERS = ("banditpam", "banditpam_pp")
REPS = 5 if FULL else 3


def _backends():
    if jax.default_backend() != "cpu" or os.environ.get(
            "REPRO_BENCH_PALLAS", "0") == "1":
        return ("jnp", "pallas")
    return ("jnp",)


def _median_phase(reports, phase):
    return round(statistics.median(
        r.wall_by_phase.get(phase, 0.0) for r in reports), 4)


def sweep(n=None, k=5, metric="l2", reps=REPS, solvers=SOLVERS):
    if n is None:
        n = 2000 if FULL else 600
    data = datasets.make("mnist_like", n, seed=0)
    rows = {}
    for s in solvers:
        for backend in _backends():
            for fused in (True, False):
                params = {**default_params(s), "backend": backend,
                          "fused": fused}
                walls, reports = [], []
                for _ in range(max(3, int(reps))):
                    est, wall = timed(lambda s=s, params=params: KMedoids(
                        k, solver=s, metric=metric, seed=0,
                        **params).fit(data))
                    walls.append(wall)
                    reports.append(est.report_)
                r = reports[-1]
                name = f"{s}[{backend},{'fused' if fused else 'stepped'}]"
                rows[name] = {
                    "solver": s,
                    "backend": backend,
                    "engine": "fused" if fused else "stepped",
                    "reps": len(walls),
                    "wall_s_median": round(statistics.median(walls), 4),
                    "wall_s_build_median": _median_phase(reports, "build"),
                    "wall_s_swap_median": _median_phase(reports, "swap"),
                    "loss": float(r.loss),
                    "n_swaps": int(r.n_swaps),
                    "ledger": r.ledger(),
                }
                emit(f"core_{name}_n{n}",
                     rows[name]["wall_s_median"] * 1e6,
                     f"build={rows[name]['wall_s_build_median']};"
                     f"swap={rows[name]['wall_s_swap_median']};"
                     f"fresh={r.distance_evals};cached={r.cached_evals}")
    return {"bench": "core", "n": int(n), "k": int(k), "metric": metric,
            "device": jax.default_backend(), "rows": rows}


def write_json(path="BENCH_core.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("core_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
