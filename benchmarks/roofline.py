"""Roofline analysis (deliverable g): three-term model per (arch x shape)
from the dry-run's compiled artifacts.

Terms (v5e, per chip): t_compute = FLOPs/197e12, t_memory = bytes/819e9,
t_collective = collective_bytes/50e9.  FLOPs/bytes are the loop-corrected
per-device totals (see repro.launch.dryrun docstring); collective bytes are
summed HLO collective result sizes (a consistent upper bound on per-chip
wire traffic).  MODEL_FLOPS = 6*N_active*D (x1 for inference cells; train
cells include the 3x backward+update factor in the 6ND convention).
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link
CHIPS = 256              # single-pod roofline table

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def load(path: str = "results/dryrun.json") -> List[dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep last record per cell
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    c = rec.get("corrected") or {
        "flops": rec["flops"], "bytes_accessed": rec["bytes_accessed"],
        "collective_bytes": rec["collective_bytes"]}
    flops = c["flops"]
    byts = c["bytes_accessed"]
    coll = sum(c["collective_bytes"].values())
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    # MODEL_FLOPS: 6ND for train (fwd+bwd), 2ND for inference cells
    mf_per_tok = (6.0 if rec["shape"] == "train_4k" else 2.0) * rec["params_active"]
    model_flops = mf_per_tok * TOKENS[rec["shape"]]
    ratio = model_flops / max(flops * CHIPS, 1.0)
    bound = max(t_c, t_m, t_x)
    frac = t_c / bound if bound > 0 else 0.0
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom, "model_flops": model_flops,
            "useful_ratio": ratio, "roofline_fraction": frac}


def gstats_intensity(m: int, n: int, d: int, k: int = 1, tm: int = 128,
                     dtype_bytes: int = 4) -> dict:
    """Analytic arithmetic-intensity terms for one g-stats dispatch:
    ``m`` candidate arms x ``n`` references x ``d`` features, ``k`` stat
    columns (1 for BUILD, k medoids for SWAP), candidate tiles of ``tm``
    rows.

    Two variants of the same FLOPs (distance matmul + the Eq. 6/Eq. 12
    clamp-and-reduce VPU tail):

    * *materialised* — the historical two-pass shape: the ``[m, n]``
      distance block is written to HBM by the pairwise pass and read
      back by the stats pass (`2·m·n` words of pure block traffic).
    * *fused* — the streaming megakernel: the block never leaves VMEM;
      HBM traffic is operands (the reference set re-read once per
      candidate tile) plus the three ``[m, k]`` stat outputs.

    The intensity gain is exactly the ratio the roofline model converts
    into wall-clock once a dispatch is memory-bound, which the
    materialised variant always is for n past a few thousand
    (ridge point ≈ PEAK_FLOPS / HBM_BW ≈ 240 FLOP/byte).
    """
    tiles = -(-m // tm)
    kp = max(int(k), 1)
    operand_bytes = float(m * d + tiles * n * d) * dtype_bytes
    out_bytes = 3.0 * m * kp * dtype_bytes
    block_bytes = 2.0 * m * n * dtype_bytes
    flops = 2.0 * m * n * d + 10.0 * m * n
    b_fused = operand_bytes + out_bytes
    b_mat = operand_bytes + out_bytes + block_bytes
    ridge = PEAK_FLOPS / HBM_BW
    return {
        "flops": flops,
        "bytes_fused": b_fused,
        "bytes_materialised": b_mat,
        "intensity_fused": flops / b_fused,
        "intensity_materialised": flops / b_mat,
        "intensity_gain": b_mat / b_fused,
        "ridge_point": ridge,
        "memory_bound_fused": flops / b_fused < ridge,
        "memory_bound_materialised": flops / b_mat < ridge,
    }


_SUGGEST = {
    "compute": "reduce recompute (remat policy) / pad-free einsums to raise "
               "useful-FLOP ratio",
    "memory": "fuse/chunk the dominant producer so activations stay on-chip; "
              "raise arithmetic intensity (larger per-step tiles)",
    "collective": "reshard to cut the biggest collective (defer grad "
                  "all-reduce out of the microbatch loop / move EP a2a "
                  "inside pod)",
}


def table(rows: List[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "6ND/HLO | next lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"].startswith("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — |")
            continue
        t = terms(r)
        if t is None:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute']:.3e} | "
            f"{t['t_memory']:.3e} | {t['t_collective']:.3e} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{_SUGGEST[t['dominant']]} |")
    return "\n".join(out)


def run():
    from .common import emit
    rows = load()
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"].startswith("skipped") for r in rows)
    emit("roofline_cells", 0.0, f"ok={n_ok};skipped={n_skip};total={len(rows)}")
    for r in rows:
        t = terms(r)
        if t is None:
            continue
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
             f"dom={t['dominant']};t_c={t['t_compute']:.3e};"
             f"t_m={t['t_memory']:.3e};t_x={t['t_collective']:.3e};"
             f"useful={t['useful_ratio']:.2f}")
    print(table(rows))
    return rows


if __name__ == "__main__":
    run()
