"""Batched multi-fit throughput: ``fit_batch`` vs the Python loop.

The tentpole claim of the batched engine (ISSUE 6): B independent
clusterings in ONE dispatch per phase beat the loop of single fits by
amortising every per-fit dispatch/sync boundary — while producing
bit-identical per-fit results (checked here on every rep, so the speedup
number can never quietly come from a divergent code path).

``benchmarks/run.py --json`` serialises this as ``BENCH_multifit.json``
(a CI artifact next to ``BENCH_core.json``).  The headline row is
``B=64, n=256``: ``fits_per_s_batch / fits_per_s_loop`` is the speedup
the acceptance gate reads (>= 3x).  Both paths are compile-warmed before
timing, so the ratio measures steady-state dispatch overhead, not XLA
compilation.

The gate is a statement about dispatch-bound runtimes.  Bit-parity pins
every batch lane to the single-fit HLO (``lax.map``, not vmap — see
``repro.core.banditpam``), so the batch can only win back what the loop
spends OUTSIDE that HLO: per-fit dispatches, host syncs, report
assembly, and the per-fit RNG-chain setup.  On an accelerator — or any
host where a ~20 ms fit is mostly launch latency — that is most of the
wall-clock and the ratio clears 3x; on a single-core CPU, where the
per-lane compute itself dominates both paths, the measured ratio
honestly reflects the smaller dispatch share (the JSON carries
``cpu_count`` so a reader can tell which regime produced the number).
"""
from __future__ import annotations

import json
import os
import statistics

import jax
import numpy as np

from repro.api import KMedoids, default_params
from repro.core import datasets

from .common import FULL, emit, timed

SOLVERS = ("banditpam", "banditpam_pp")
REPS = 5 if FULL else 3
B, N, K = 64, 256, 5


def _datasets(batch, n, d_seed=0):
    return [np.asarray(datasets.make("mnist_like", n, seed=d_seed + i),
                       np.float32) for i in range(batch)]


def sweep(batch=B, n=N, k=K, metric="l2", reps=REPS, solvers=SOLVERS):
    Xs = _datasets(batch, n)
    seeds = list(range(batch))
    rows = {}
    for s in solvers:
        params = {**default_params(s), "backend": "jnp"}
        est = KMedoids(k, solver=s, metric=metric, seed=0, **params)
        # warm both compile caches OUTSIDE the timed region
        est.fit(Xs[0])
        ref = est.fit_batch(Xs, seeds=seeds)

        walls_loop, walls_batch = [], []
        for _ in range(max(3, int(reps))):
            singles, wall = timed(lambda s=s, params=params: [
                KMedoids(k, solver=s, metric=metric, seed=sd, **params
                         ).fit(Xs[i]).report_
                for i, sd in enumerate(seeds)])
            walls_loop.append(wall)
            rep, wall = timed(lambda: est.fit_batch(Xs, seeds=seeds))
            walls_batch.append(wall)
            # the speedup only counts if the answers are the same answers
            for i, single in enumerate(singles):
                assert np.array_equal(np.asarray(rep[i].medoids),
                                      np.asarray(single.medoids)), (s, i)
                assert rep[i].distance_evals == single.distance_evals, (s, i)
        wl = statistics.median(walls_loop)
        wb = statistics.median(walls_batch)
        rows[s] = {
            "solver": s,
            "reps": len(walls_loop),
            "wall_s_loop_median": round(wl, 4),
            "wall_s_batch_median": round(wb, 4),
            "fits_per_s_loop": round(batch / wl, 2),
            "fits_per_s_batch": round(batch / wb, 2),
            "speedup": round(wl / wb, 2),
            "dispatches_by_phase": dict(ref.dispatches_by_phase),
            "loss_sum": round(float(np.sum(ref.loss)), 2),
        }
        emit(f"multifit_{s}_B{batch}_n{n}", wb / batch * 1e6,
             f"speedup={rows[s]['speedup']};"
             f"fits_per_s={rows[s]['fits_per_s_batch']};"
             f"loop_fits_per_s={rows[s]['fits_per_s_loop']}")
    return {"bench": "multifit", "B": int(batch), "n": int(n), "k": int(k),
            "metric": metric, "device": jax.default_backend(),
            "cpu_count": os.cpu_count(), "rows": rows}


def write_json(path="BENCH_multifit.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("multifit_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
