"""Kernel-layer microbenchmark: fused Pallas g-statistics vs the unfused
jnp path.  On this CPU container the Pallas kernels execute in interpret
mode, so the *wall-clock* comparison that matters is the jnp-fused vs
jnp-unfused path (the HBM-traffic argument for the TPU kernel is made in
the kernel docstrings and EXPERIMENTS.md §Roofline); the Pallas call is
timed to confirm interpret-mode validity, not speed."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.banditpam import _build_g
from repro.core.distances import l2
from repro.kernels import ops, ref

from .common import FULL, emit


def _time(fn, *args, reps=3):
    fn(*args)                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run():
    n, b, d = (4096, 512, 784) if FULL else (1024, 256, 784)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    dn = jnp.asarray(rng.uniform(0.5, 2.0, b).astype(np.float32))
    w = jnp.ones((b,), jnp.float32)

    @jax.jit
    def unfused(x, y, dn, w):
        dxy = l2(x, y)                       # [n, b] materialized
        g = _build_g(dxy, dn) * w[None]
        return g.sum(1), (g * g).sum(1)

    @jax.jit
    def fused_jnp(x, y, dn, w):              # same math, fused by XLA
        g = _build_g(l2(x, y), dn) * w[None]
        return g.sum(1), (g * g).sum(1)

    t_un = _time(unfused, x, y, dn, w)
    emit("kernel_build_g_jnp", t_un * 1e6, f"n={n};b={b};d={d}")
    t_pallas = _time(lambda: ops.build_g_stats(x, y, dn, w, metric="l2",
                                               interpret=True)[0])
    emit("kernel_build_g_pallas_interpret", t_pallas * 1e6,
         "correctness-mode (CPU interpret); TPU perf via VMEM-fusion design")
    # correctness cross-check as part of the bench
    s_p, q_p, _ = ops.build_g_stats(x, y, dn, w, metric="l2", interpret=True)
    s_r, q_r = ref.build_g_ref(x, y, dn, w, "l2")
    err = float(jnp.max(jnp.abs(s_p - s_r)))
    emit("kernel_build_g_maxerr", 0.0, f"{err:.2e}")
    assert err < 5e-2

    # streaming megakernel: same stats accumulated over a reference WALK
    # (b unbounded) instead of a resident batch — validity + wall here,
    # the HBM-traffic argument lives in benchmarks/megakernel_bench.py
    t_stream = _time(lambda: ops.stream_build_g_stats(
        x[:256], x, jnp.broadcast_to(dn[0], (n,)), metric="l2",
        interpret=True)[0])
    emit("kernel_stream_build_g_pallas_interpret", t_stream * 1e6,
         f"m=256;r={n};d={d} (reference walk, correctness-mode)")
    s_s, _, _ = ops.stream_build_g_stats(
        x[:256], x, jnp.broadcast_to(dn[0], (n,)), metric="l2",
        interpret=True)
    s_o, _ = ref.build_g_ref(x[:256], x, jnp.broadcast_to(dn[0], (n,)),
                             jnp.ones((n,), jnp.float32), "l2")
    err_s = float(jnp.max(jnp.abs(s_s - s_o)))
    emit("kernel_stream_build_g_maxerr", 0.0, f"{err_s:.2e}")
    assert err_s < 5e-2


if __name__ == "__main__":
    run()
