"""Paper Fig. 1a: clustering loss relative to PAM.

BanditPAM must sit at ratio 1.0 (same medoids as PAM); CLARANS and
Voronoi Iteration are the quality-sacrificing baselines; CLARA included
for completeness.  Every algorithm runs through the ``repro.api.KMedoids``
facade, so adding a registered solver to ``SOLVER_PARAMS`` adds it to the
figure."""
from __future__ import annotations

import numpy as np

from repro.api import KMedoids, default_params

from repro.core import datasets

from .common import BENCH_EXTRA, FULL, emit, timed

SOLVERS = ["banditpam", "clarans", "voronoi", "clara"]


def run():
    sizes = [500, 1000, 2000, 3000] if FULL else [300, 600]
    reps = 5 if FULL else 2
    k = 5
    rows = {}
    for n in sizes:
        ratios = {s: [] for s in SOLVERS}
        tb = 0.0
        for rep in range(reps):
            data = datasets.mnist_like(n, seed=100 + rep)
            p, tp = timed(lambda data=data: KMedoids(k, solver="fastpam1",
                                                     metric="l2").fit(data))
            for s in SOLVERS:
                params = {**default_params(s), **BENCH_EXTRA.get(s, {})}
                r, tr = timed(lambda s=s, rep=rep, params=params, data=data:
                              KMedoids(k, solver=s, metric="l2",
                                       seed=rep, **params).fit(data))
                if s == "banditpam":
                    tb = tr
                ratios[s].append(r.loss_ / p.loss_)
        rows[n] = {s: float(np.mean(v)) for s, v in ratios.items()}
        emit(f"fig1a_loss_ratio_n{n}", tb * 1e6 / max(1, n),
             ";".join(f"{s}={v:.4f}" for s, v in rows[n].items()))
    # invariant from the paper: BanditPAM == PAM, others >= 1
    worst = max(v["banditpam"] for v in rows.values())
    emit("fig1a_banditpam_worst_ratio", 0.0, f"{worst:.6f}")
    return rows


if __name__ == "__main__":
    run()
