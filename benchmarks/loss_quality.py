"""Paper Fig. 1a: clustering loss relative to PAM.

BanditPAM must sit at ratio 1.0 (same medoids as PAM); CLARANS and
Voronoi Iteration are the quality-sacrificing baselines; CLARA included
for completeness."""
from __future__ import annotations

import numpy as np

from repro.core import BanditPAM, clara, clarans, pam, voronoi_iteration
from repro.core import datasets

from .common import FULL, emit, timed


def run():
    sizes = [500, 1000, 2000, 3000] if FULL else [300, 600]
    reps = 5 if FULL else 2
    k = 5
    rows = {}
    for n in sizes:
        ratios = {"banditpam": [], "clarans": [], "voronoi": [], "clara": []}
        for rep in range(reps):
            data = datasets.mnist_like(n, seed=100 + rep)
            p, tp = timed(pam, data, k, "l2")
            b, tb = timed(lambda: BanditPAM(k, "l2", seed=rep,
                                            baseline="leader").fit(data))
            c = clarans(data, k, "l2", seed=rep, max_neighbors=150)
            v = voronoi_iteration(data, k, "l2", seed=rep)
            cl = clara(data, k, "l2", seed=rep)
            ratios["banditpam"].append(b.loss / p.loss)
            ratios["clarans"].append(c.loss / p.loss)
            ratios["voronoi"].append(v.loss / p.loss)
            ratios["clara"].append(cl.loss / p.loss)
        rows[n] = {a: float(np.mean(r)) for a, r in ratios.items()}
        emit(f"fig1a_loss_ratio_n{n}", tb * 1e6 / max(1, n),
             ";".join(f"{a}={v:.4f}" for a, v in rows[n].items()))
    # invariant from the paper: BanditPAM == PAM, others >= 1
    worst = max(v["banditpam"] for v in rows.values())
    emit("fig1a_banditpam_worst_ratio", 0.0, f"{worst:.6f}")
    return rows


if __name__ == "__main__":
    run()
