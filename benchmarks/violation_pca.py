"""Paper Appendix 1.3 / Fig. 5: scaling degradation when the distributional
assumptions are violated (scRNA-PCA-like: arm means concentrated near the
minimum, heavy-tailed rewards).  The paper reports ~n^1.2 here vs ~n^1.0
on well-behaved data; we reproduce the *gap* between the two regimes."""
from __future__ import annotations

from repro.core import BanditPAM, datasets

from .common import FULL, emit, loglog_slope, timed


def run():
    sizes = [1000, 2000, 4000] if FULL else [500, 1000, 2000]
    k = 5
    slopes = {}
    for ds, metric in (("scrna_pca_like", "l2"), ("mnist_like", "l2")):
        evs = []
        for n in sizes:
            data = datasets.make(ds, n, seed=11)
            b, wall = timed(lambda metric=metric, data=data:
                            BanditPAM(k, metric, seed=0,
                                      baseline="leader").fit(data))
            iters = k + b.n_swaps + 1
            evs.append(b.distance_evals / iters)
            emit(f"appfig5_{ds}_n{n}", wall * 1e6,
                 f"evals_per_iter={evs[-1]:.0f}")
        slopes[ds] = loglog_slope(sizes, evs)
        emit(f"appfig5_{ds}_slope", 0.0, f"slope={slopes[ds]:.3f}")
    gap = slopes["scrna_pca_like"] - slopes["mnist_like"]
    emit("appfig5_violation_gap", 0.0, f"gap={gap:.3f} (paper: ~+0.2)")
    return slopes


if __name__ == "__main__":
    run()
