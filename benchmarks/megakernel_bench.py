"""Streaming g-stats megakernel benchmark (docs/design.md #8).

Three measurement families, written to ``BENCH_megakernel.json`` for the
CI artifact:

* ``wall`` — streaming vs materialised walls for the loss / top-2 /
  exact-fallback dispatches on the jnp lane (the CPU-honest comparison;
  the Pallas lane is interpret-mode here and is opt-in via
  ``REPRO_BENCH_PALLAS=1``, timed for validity rather than speed).
* ``temp_bytes`` — compiled peak-temp deltas from
  ``jit(...).lower().compile().memory_analysis()``: the streaming forms
  must not hold the O(n·k) / O(n·chunk) block the materialised graphs
  carry.
* ``intensity`` — analytic arithmetic-intensity deltas from
  ``benchmarks.roofline.gstats_intensity`` at serving/fit shapes: the
  fused walk's FLOP/byte gain is what the TPU roofline converts into
  wall-clock once the dispatch is memory-bound.

The tile-tuner sweep at the end seeds ``repro.core.tuning``'s measured
ledger (``candidates()`` → ``observe()``) and records which config won,
so a serving process can replay the same warmup.
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, tuning
from repro.core.distances import get_metric

from .common import FULL, emit
from .roofline import gstats_intensity


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))            # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _temp_bytes(fn, *specs):
    ma = jax.jit(fn).lower(*specs).compile().memory_analysis()
    return None if ma is None else int(ma.temp_size_in_bytes)


def _mat_loss(metric):
    def f(data, medoids):
        dmat = get_metric(metric)(data, data[medoids])
        return jnp.sum(jnp.min(dmat, axis=1))
    return f


def _mat_cache(metric):
    def f(data, medoids):
        dmat = get_metric(metric)(data, data[medoids])
        assign = jnp.argmin(dmat, axis=1).astype(jnp.int32)
        d1 = jnp.min(dmat, axis=1)
        dmat2 = dmat.at[jnp.arange(dmat.shape[0]), assign].set(jnp.inf)
        return d1, jnp.min(dmat2, axis=1), assign
    return f


def _chunked_build(be, metric, n):
    """The pre-streaming exact-fallback graph (scan with a resident
    [n, chunk] block) — the baseline the megakernel replaces."""
    def f(data, dnear):
        idx_np, w_np = engine._ref_chunks(n, engine._EXACT_CHUNK)
        idx, w = jnp.asarray(idx_np), jnp.asarray(w_np)

        def body(acc, iw):
            i, w_i = iw
            dxy = be.pairwise(data, data[i], metric=metric)
            s, _, _ = be.build_stats_from_d(dxy, dnear[i], w_i, None)
            return acc + s, None

        sums, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                               (idx, w))
        return sums / n
    return f


def sweep(metric: str = "l2") -> dict:
    n, k = (20_000, 64) if FULL else (4_000, 32)
    d = 32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    med = jnp.asarray(rng.choice(n, k, replace=False).astype(np.int32))
    dnear = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    be = engine.get_stats_backend("jnp")
    payload = {"shape": {"n": n, "d": d, "k": k, "metric": metric},
               "wall": {}, "temp_bytes": {}, "intensity": {}, "tuner": {}}

    # -- walls: streaming vs materialised ------------------------------
    pairs = {
        "loss": (jax.jit(functools.partial(engine.total_loss,
                                           metric=metric)),
                 jax.jit(_mat_loss(metric)), (x, med)),
        "top2": (jax.jit(functools.partial(engine.medoid_cache,
                                           metric=metric)),
                 jax.jit(_mat_cache(metric)), (x, med)),
        "exact_build": (jax.jit(lambda a, b: engine.exact_build_means(
                            be, a, b, metric=metric)),
                        jax.jit(_chunked_build(be, metric, n)), (x, dnear)),
    }
    for name, (stream_fn, mat_fn, args) in pairs.items():
        t_s = _time(stream_fn, *args)
        t_m = _time(mat_fn, *args)
        payload["wall"][name] = {"stream_s": t_s, "materialised_s": t_m,
                                 "speedup": t_m / t_s}
        emit(f"megakernel_{name}_stream", t_s * 1e6,
             f"n={n};k={k};mat_us={t_m * 1e6:.1f};x{t_m / t_s:.2f}")

    # -- compiled temp deltas ------------------------------------------
    xs = jax.ShapeDtypeStruct((n, d), jnp.float32)
    ms = jax.ShapeDtypeStruct((k,), jnp.int32)
    ds = jax.ShapeDtypeStruct((n,), jnp.float32)
    temp_specs = {
        "loss": (functools.partial(engine.total_loss, metric=metric),
                 _mat_loss(metric), (xs, ms)),
        "top2": (functools.partial(engine.medoid_cache, metric=metric),
                 _mat_cache(metric), (xs, ms)),
        "exact_build": (lambda a, b: engine.exact_build_means(
                            be, a, b, metric=metric),
                        _chunked_build(be, metric, n), (xs, ds)),
    }
    for name, (stream_fn, mat_fn, specs) in temp_specs.items():
        b_s = _temp_bytes(stream_fn, *specs)
        b_m = _temp_bytes(mat_fn, *specs)
        payload["temp_bytes"][name] = {"stream": b_s, "materialised": b_m}
        if b_s and b_m:
            emit(f"megakernel_{name}_temp", 0.0,
                 f"stream={b_s};materialised={b_m};x{b_m / b_s:.1f}")

    # -- arithmetic-intensity deltas (roofline model) ------------------
    for label, (m_, n_, k_) in {
        "exact_build_1e6": (1_000_000, 1_000_000, 1),
        "swap_round": (100_000, 512, 8),
        "serve_top2_1e6": (1_000_000, 8, 8),
    }.items():
        payload["intensity"][label] = gstats_intensity(m_, n_, d=128, k=k_)
        g = payload["intensity"][label]["intensity_gain"]
        emit(f"megakernel_intensity_{label}", 0.0, f"gain=x{g:.1f}")

    # -- tile-tuner sweep (seeds the measured ledger) ------------------
    tuning.clear_ledger()
    for cfg in tuning.candidates(n, d, k, backend="jnp"):
        t = _time(jax.jit(functools.partial(engine.total_loss,
                                            metric=metric, tile=cfg.tm)),
                  x, med)
        tuning.observe(n, d, k, cfg, {"loss": t}, backend="jnp")
        payload["tuner"][f"tm{cfg.tm}"] = t
    best = tuning.resolve_tile_config(n, d, k, backend="jnp")
    payload["tuner"]["resolved_tm"] = best.tm
    emit("megakernel_tuner_resolved", 0.0,
         f"tm={best.tm};tb={best.tb};candidates={len(payload['tuner']) - 1}")

    if os.environ.get("REPRO_BENCH_PALLAS") == "1":
        from repro.kernels import ops
        t = _time(functools.partial(ops.stream_build_g_stats, metric=metric,
                                    interpret=True), x[:256], x, dnear)
        payload["wall"]["pallas_stream_build_interpret"] = t
        emit("megakernel_pallas_interpret", t * 1e6, f"n={n}")
    return payload


def write_json(path="BENCH_megakernel.json", **kw) -> str:
    payload = sweep(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("megakernel_json_written", 0.0, path)
    return path


def run():
    sweep()


if __name__ == "__main__":
    run()
